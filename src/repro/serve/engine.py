"""Continuous-batching serving engine (decode slots + prefill insertion).

A compact but real engine: fixed decode slots share one batched KV cache;
requests are prefilled one at a time (prefill batch = 1 here; the dry-run
exercises the big prefill shapes) and inserted into free slots; every decode
step advances all live slots together.  Finished sequences free their slot.

The engine is deliberately model-agnostic: it drives the ``Model`` API
(prefill / decode_step) that every one of the ten architectures implements.

``paged_kv=True`` replaces the dense per-slot KV with the **paged pool
layout** of the disaggregated serving runtime (``repro.serve.disagg``): the
self-attention cache becomes a physical page pool plus a per-row page table,
pages are allocated from a :class:`~repro.serve.disagg.PageAllocator` at
slot admission and freed at release, and the decode path runs through the
page-table indirection in ``models/attention.py``.  This is exactly the
cache a decode worker owns in a prefill→decode split — the pool a remote
prefill engine pushes pages into through memory handles — so the engine
doubles as the decode half of the disagg deployment.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int
    eos_id: int = -1            # -1: never stops early


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list


def _paged_dicts(tree):
    """Yield every dict node of a cache tree (to probe for paged leaves)."""
    if isinstance(tree, dict):
        yield tree
        for v in tree.values():
            yield from _paged_dicts(v)
    elif isinstance(tree, list):
        for v in tree:
            yield from _paged_dicts(v)


def _insert_row(full: Array, one: Array, slot, n_slots: int) -> Array:
    """Scatter a 1-row leaf into the n_slots-row leaf along the batch axis.

    The batch axis is wherever `one` is 1 and `full` is n_slots with all
    other dims equal (scan-stacked leaves carry a leading layers dim, so it
    is not always axis 0)."""
    if full.ndim != one.ndim:
        return full
    for ax in range(full.ndim):
        rest_f = full.shape[:ax] + full.shape[ax + 1:]
        rest_o = one.shape[:ax] + one.shape[ax + 1:]
        if (one.shape[ax] == 1 and full.shape[ax] == n_slots
                and rest_f == rest_o):
            starts = [0] * full.ndim
            starts[ax] = slot
            return jax.lax.dynamic_update_slice(
                full, one.astype(full.dtype), tuple(starts))
    return full


class ServeEngine:
    """Greedy-decoding continuous-batching engine over ``n_slots`` slots."""

    def __init__(self, model, params, *, n_slots: int, max_seq: int,
                 enc_len: int = 0, paged_kv: bool = False,
                 page_tokens: int = 16):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        cfg = model.cfg
        self.cache = model.init_cache(n_slots, max_seq, enc_len=enc_len)
        self.paged_kv = paged_kv
        if paged_kv:
            from repro.serve import disagg

            paged_cache = disagg.paginate_cache(self.cache, page_tokens)
            if not any("k_pages" in d for d in _paged_dicts(paged_cache)):
                raise ValueError(
                    f"paged_kv=True but the {cfg.family!r} stack has no "
                    "self-attention KV caches to page (MLA/SSM caches stay "
                    "dense) — the paged data plane would be a no-op")
            self.cache = paged_cache
            self.page_tokens = page_tokens
            self.pages_per_slot = max_seq // page_tokens
            self.allocator = disagg.PageAllocator(
                n_slots * self.pages_per_slot)
            self.slot_pages: dict[int, list[int]] = {}
        self.slot_free = [True] * n_slots
        self.slot_req: dict[int, Request] = {}
        self.slot_generated: dict[int, list] = {}
        self.slot_pos: dict[int, int] = {}
        self.pending: list[Request] = []
        self.done: list[Completion] = []
        self._decode = jax.jit(model.decode_step)
        self._last_tokens = jnp.zeros((n_slots, 1), jnp.int32)

        # single-sequence prefill that scatters into one cache slot; in paged
        # mode the dense prefill KV is re-paged into the slot's physical
        # pages and the slot's page-table row is wired up
        def prefill_into_slot(params, cache, tokens, slot, phys_pages):
            sub = model.init_cache(1, max_seq, enc_len=enc_len)
            logits, sub = model.prefill(params, {"tokens": tokens}, sub)
            cache2 = self._insert(cache, sub, slot, phys_pages)
            return logits, cache2

        self._prefill = jax.jit(prefill_into_slot, static_argnames=())

    # -- cache insertion ---------------------------------------------------------
    def _insert(self, full, one, slot, phys_pages):
        """Insert the freshly prefilled 1-row cache ``one`` into slot ``slot``
        of the engine cache ``full`` (recursive walk; paged attention dicts
        scatter through the page table, everything else along the batch
        axis)."""
        if isinstance(full, dict):
            if "k_pages" in full:
                return self._insert_paged_attn(full, one, slot, phys_pages)
            return {key: self._insert(full[key], one[key], slot, phys_pages)
                    for key in full}
        if isinstance(full, list):
            return [self._insert(f, o, slot, phys_pages)
                    for f, o in zip(full, one)]
        return _insert_row(full, one, slot, self.n_slots)

    def _insert_paged_attn(self, full, one, slot, phys_pages):
        """Scatter a dense (1, S, KV, hd) prefill KV into the slot's physical
        pages and point the slot's page-table row at them."""
        pt = self.page_tokens

        def repage_scatter(pool, dense):
            *lead, _, s, kv, hd = dense.shape
            d = dense.reshape(*lead, s // pt, pt, kv, hd).astype(pool.dtype)
            if pool.ndim == 4:
                return pool.at[phys_pages].set(d)
            return pool.at[:, phys_pages].set(d)   # leading scan dim

        table, pos = full["page_table"], full["pos"]
        if table.ndim == 2:
            table = table.at[slot].set(phys_pages)
            pos = pos.at[slot].set(one["pos"][0])
        else:
            table = table.at[:, slot].set(phys_pages)
            pos = pos.at[:, slot].set(one["pos"][:, 0])
        return dict(
            full,
            k_pages=repage_scatter(full["k_pages"], one["k"]),
            v_pages=repage_scatter(full["v_pages"], one["v"]),
            page_table=table,
            pos=pos,
        )

    # -- public API --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.max_seq:
            raise ValueError("prompt longer than max_seq")
        self.pending.append(req)

    def step(self) -> None:
        """One engine tick: admit pending requests, then one decode step."""
        self._admit()
        if not self.slot_req:
            return
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._last_tokens)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        nxt_np = np.asarray(nxt)
        new_last = np.asarray(self._last_tokens).copy()
        for slot in list(self.slot_req):
            tok = int(nxt_np[slot])
            self.slot_generated[slot].append(tok)
            self.slot_pos[slot] += 1
            new_last[slot, 0] = tok
            self._finish_if_ended(slot)
        self._last_tokens = jnp.asarray(new_last)

    def run(self, max_ticks: int = 10_000) -> list[Completion]:
        ticks = 0
        while (self.pending or self.slot_req) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done

    def stats(self) -> dict:
        """Engine health: completions + the paged pool's allocator state."""
        out = {"completed": len(self.done), "pending": len(self.pending),
               "live_slots": len(self.slot_req), "paged_kv": self.paged_kv}
        if self.paged_kv:
            out.update(pages_allocated=self.allocator.allocs,
                       pages_freed=self.allocator.frees,
                       pages_free=self.allocator.n_free,
                       page_tokens=self.page_tokens)
        return out

    # -- internals --------------------------------------------------------------
    def _finish_if_ended(self, slot: int) -> bool:
        """Complete-and-release ``slot`` iff its latest token terminates the
        request (EOS, token budget, or cache full) — the single termination
        predicate shared by the decode loop and admission-time prefill."""
        req = self.slot_req[slot]
        gen = self.slot_generated[slot]
        ended = (gen[-1] == req.eos_id or
                 len(gen) >= req.max_new_tokens or
                 self.slot_pos[slot] >= self.max_seq - 1)
        if ended:
            self.done.append(Completion(req.rid, gen))
            self._release(slot)
        return ended

    def _admit(self) -> None:
        while self.pending and any(self.slot_free):
            req = self.pending.pop(0)
            slot = self.slot_free.index(True)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            if self.paged_kv:
                phys = self.allocator.alloc(self.pages_per_slot)
                self.slot_pages[slot] = phys
                phys_arg = jnp.asarray(phys, jnp.int32)
            else:
                phys_arg = jnp.zeros((0,), jnp.int32)
            logits, self.cache = self._prefill(self.params, self.cache,
                                               tokens, slot, phys_arg)
            first = int(np.asarray(jnp.argmax(logits[0, -1])))
            self.slot_free[slot] = False
            self.slot_req[slot] = req
            self.slot_generated[slot] = [first]
            self.slot_pos[slot] = len(req.prompt) + 1
            # the prefill token can already terminate the request (EOS, or
            # max_new_tokens=1, or the cache is full): complete-and-release
            # here, or the slot decodes a spurious extra step — and in paged
            # mode holds its KV pages — for a full extra tick
            if self._finish_if_ended(slot):
                continue
            lt = np.asarray(self._last_tokens).copy()
            lt[slot, 0] = first
            self._last_tokens = jnp.asarray(lt)

    def _release(self, slot: int) -> None:
        self.slot_free[slot] = True
        del self.slot_req[slot]
        del self.slot_generated[slot]
        del self.slot_pos[slot]
        if self.paged_kv and slot in self.slot_pages:
            from repro.serve import disagg

            # park the row before its pages go back to the free list: idle
            # rows keep scattering per-step KV, and those writes must never
            # land on pages a later admission may own
            self.cache = disagg.park_slot(self.cache, slot)
            self.allocator.free(self.slot_pages.pop(slot))


__all__ = ["ServeEngine", "Request", "Completion"]
