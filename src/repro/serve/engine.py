"""Continuous-batching serving engine (decode slots + prefill insertion).

A compact but real engine: fixed decode slots share one batched KV cache;
requests are prefilled one at a time (prefill batch = 1 here; the dry-run
exercises the big prefill shapes) and inserted into free slots; every decode
step advances all live slots together.  Finished sequences free their slot.

The engine is deliberately model-agnostic: it drives the ``Model`` API
(prefill / decode_step) that every one of the ten architectures implements.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int
    eos_id: int = -1            # -1: never stops early


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list


class ServeEngine:
    """Greedy-decoding continuous-batching engine over ``n_slots`` slots."""

    def __init__(self, model, params, *, n_slots: int, max_seq: int,
                 enc_len: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        cfg = model.cfg
        self.cache = model.init_cache(n_slots, max_seq, enc_len=enc_len)
        self.slot_free = [True] * n_slots
        self.slot_req: dict[int, Request] = {}
        self.slot_generated: dict[int, list] = {}
        self.slot_pos: dict[int, int] = {}
        self.pending: list[Request] = []
        self.done: list[Completion] = []
        self._decode = jax.jit(model.decode_step)
        self._last_tokens = jnp.zeros((n_slots, 1), jnp.int32)

        # single-sequence prefill that scatters into one cache slot
        def prefill_into_slot(params, cache, tokens, slot):
            sub = model.init_cache(1, max_seq, enc_len=enc_len)
            logits, sub = model.prefill(params, {"tokens": tokens}, sub)

            def insert(full, one):
                # The batch axis is wherever `one` is 1 and `full` is
                # n_slots with all other dims equal (scan-stacked leaves
                # carry a leading layers dim, so it is not always axis 0).
                if full.ndim != one.ndim:
                    return full
                for ax in range(full.ndim):
                    rest_f = full.shape[:ax] + full.shape[ax + 1:]
                    rest_o = one.shape[:ax] + one.shape[ax + 1:]
                    if (one.shape[ax] == 1 and full.shape[ax] == n_slots
                            and rest_f == rest_o):
                        starts = [0] * full.ndim
                        starts[ax] = slot
                        return jax.lax.dynamic_update_slice(
                            full, one.astype(full.dtype), tuple(starts))
                return full
            cache2 = jax.tree.map(insert, cache, sub)
            return logits, cache2

        self._prefill = jax.jit(prefill_into_slot, static_argnames=())

    # -- public API --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.max_seq:
            raise ValueError("prompt longer than max_seq")
        self.pending.append(req)

    def step(self) -> None:
        """One engine tick: admit pending requests, then one decode step."""
        self._admit()
        if not self.slot_req:
            return
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._last_tokens)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        nxt_np = np.asarray(nxt)
        new_last = np.asarray(self._last_tokens).copy()
        for slot, req in list(self.slot_req.items()):
            tok = int(nxt_np[slot])
            self.slot_generated[slot].append(tok)
            self.slot_pos[slot] += 1
            new_last[slot, 0] = tok
            ended = (tok == req.eos_id or
                     len(self.slot_generated[slot]) >= req.max_new_tokens or
                     self.slot_pos[slot] >= self.max_seq - 1)
            if ended:
                self.done.append(Completion(req.rid, self.slot_generated[slot]))
                self._release(slot)
        self._last_tokens = jnp.asarray(new_last)

    def run(self, max_ticks: int = 10_000) -> list[Completion]:
        ticks = 0
        while (self.pending or self.slot_req) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done

    # -- internals --------------------------------------------------------------
    def _admit(self) -> None:
        while self.pending and any(self.slot_free):
            req = self.pending.pop(0)
            slot = self.slot_free.index(True)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, self.cache = self._prefill(self.params, self.cache,
                                               tokens, slot)
            first = int(np.asarray(jnp.argmax(logits[0, -1])))
            self.slot_free[slot] = False
            self.slot_req[slot] = req
            self.slot_generated[slot] = [first]
            self.slot_pos[slot] = len(req.prompt) + 1
            lt = np.asarray(self._last_tokens).copy()
            lt[slot, 0] = first
            self._last_tokens = jnp.asarray(lt)

    def _release(self, slot: int) -> None:
        self.slot_free[slot] = True
        del self.slot_req[slot]
        del self.slot_generated[slot]
        del self.slot_pos[slot]


__all__ = ["ServeEngine", "Request", "Completion"]
