"""The serving engine: scheduler / KV pool / executor, continuous batching.

The engine is three explicit layers (``docs/serving_disagg.md``):

* :class:`repro.serve.scheduler.Scheduler` — the **policy** layer: request
  queue (arrival ticks, priorities, tenants) and per-tick admission.
  Continuous batching means admission happens *every decode tick* into any
  free slot, not only between whole batches; the same policy object drives
  the disagg control window's fetch_op ticket budget
  (:func:`repro.serve.disagg.claim_slots`).
* :class:`repro.serve.paged.KVPoolManager` — the **pool** layer: refcounts
  on physical KV pages, copy-on-write prefix sharing (sequences with a
  common prompt prefix map the *same* physical pages and fork only on the
  first divergent write), FIFO free list, double-free guards.
* :class:`Executor` (here) — the **execution** layer: owns the batched
  device cache and the jitted prefill/decode, and runs exactly what the
  scheduler admitted this tick.  It knows nothing about queues or
  refcounts; the facade hands it slots, physical pages, and a write mask.

:class:`ServeEngine` is the facade wiring the three together, keeping the
original public surface (``submit`` / ``step`` / ``run`` / ``stats``,
``slot_free`` / ``slot_req`` / ``done``).  Greedy decode is bit-identical
to the previous monolithic engine — the layers change who decides, not
what runs.

``paged_kv=True`` replaces the dense per-slot KV with the **paged pool
layout** of the disaggregated serving runtime (``repro.serve.disagg``): the
self-attention cache becomes a physical page pool plus a per-row page
table — exactly the cache a decode worker owns in a prefill→decode split.
``prefix_share=True`` additionally admits new requests onto the pages of a
live request with a common prompt prefix:

* full pages entirely inside the common prefix are mapped **immutably**
  (refcount+1, write-protected device-side via the cache's ``page_ro``
  leaf — decode scatters at them are dropped like overflow writes);
* the one partial page at the prefix boundary is mapped **copy-on-write**
  when the new prompt ends exactly at the prefix (both holders will write
  it): the engine forks it — device page copy + table remap — the tick a
  holder's write position reaches it while the refcount is still > 1.

Sharing is safe on two grounds: KV at position *i* depends only on tokens
``0..i`` (identical prefixes ⇒ bit-identical pages, prefilled by the same
jitted function), and decode is write-then-attend (a forked copy's stale
positions are overwritten before their causal mask ever opens).  The
pool's :meth:`~repro.serve.paged.KVPoolManager.can_admit` reserves one
free page per outstanding writable share, so a fork can never find the
free list empty.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.paged import KVPoolManager
from repro.serve.scheduler import Scheduler

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int
    eos_id: int = -1            # -1: never stops early
    priority: int = 0           # policy="priority": higher admits first
    tenant: int = 0             # policy="fair": fair-share key


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list
    finished: bool = True       # False: run() ran out of ticks (partial)
    arrival_tick: int = 0
    done_tick: int = 0


def _paged_dicts(tree):
    """Yield every dict node of a cache tree (to probe for paged leaves)."""
    if isinstance(tree, dict):
        yield tree
        for v in tree.values():
            yield from _paged_dicts(v)
    elif isinstance(tree, list):
        for v in tree:
            yield from _paged_dicts(v)


def _map_paged(cache, fn):
    """Rebuild a cache tree applying ``fn`` to every paged-attention dict."""
    if isinstance(cache, dict):
        if "k_pages" in cache:
            return fn(cache)
        return {k: _map_paged(v, fn) for k, v in cache.items()}
    if isinstance(cache, list):
        return [_map_paged(v, fn) for v in cache]
    return cache


def _insert_row(full: Array, one: Array, slot, n_slots: int) -> Array:
    """Scatter a 1-row leaf into the n_slots-row leaf along the batch axis.

    The batch axis is wherever `one` is 1 and `full` is n_slots with all
    other dims equal (scan-stacked leaves carry a leading layers dim, so it
    is not always axis 0)."""
    if full.ndim != one.ndim:
        return full
    for ax in range(full.ndim):
        rest_f = full.shape[:ax] + full.shape[ax + 1:]
        rest_o = one.shape[:ax] + one.shape[ax + 1:]
        if (one.shape[ax] == 1 and full.shape[ax] == n_slots
                and rest_f == rest_o):
            starts = [0] * full.ndim
            starts[ax] = slot
            return jax.lax.dynamic_update_slice(
                full, one.astype(full.dtype), tuple(starts))
    return full


class Executor:
    """The execution layer: batched cache + jitted prefill/decode.

    Decisions live elsewhere — the scheduler picks *what* runs, the pool
    manager picks *which pages* back it; the executor is handed a slot, a
    physical-page row, and a per-page write mask, and runs the model."""

    def __init__(self, model, params, *, n_slots: int, max_seq: int,
                 enc_len: int = 0, paged_kv: bool = False,
                 page_tokens: int = 16):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.page_tokens = page_tokens
        self.cache = model.init_cache(n_slots, max_seq, enc_len=enc_len)
        self.paged_kv = paged_kv
        if paged_kv:
            from repro.serve import disagg

            paged_cache = disagg.paginate_cache(self.cache, page_tokens)
            if not any("k_pages" in d for d in _paged_dicts(paged_cache)):
                raise ValueError(
                    f"paged_kv=True but the {model.cfg.family!r} stack has "
                    "no self-attention KV caches to page (MLA/SSM caches "
                    "stay dense) — the paged data plane would be a no-op")
            self.cache = paged_cache
        self._decode_fn = jax.jit(model.decode_step)

        # single-sequence prefill that scatters into one cache slot; in
        # paged mode the dense prefill KV is re-paged into the slot's
        # physical pages (write-masked pages land on the parking page —
        # they are shared, their contents already prefilled by the donor)
        # and the slot's page-table row is wired up
        def prefill_into_slot(params, cache, tokens, slot, phys_pages,
                              write_ok):
            sub = model.init_cache(1, max_seq, enc_len=enc_len)
            logits, sub = model.prefill(params, {"tokens": tokens}, sub)
            cache2 = self._insert(cache, sub, slot, phys_pages, write_ok)
            return logits, cache2

        self._prefill_fn = jax.jit(prefill_into_slot)

    # -- the two model calls ----------------------------------------------------
    def prefill(self, tokens: Array, slot: int, phys_pages: Array,
                write_ok: Array) -> int:
        """Prefill one admitted request into ``slot``; returns its first
        greedy token."""
        logits, self.cache = self._prefill_fn(self.params, self.cache,
                                              tokens, slot, phys_pages,
                                              write_ok)
        return int(np.asarray(jnp.argmax(logits[0, -1])))

    def decode(self, last_tokens: np.ndarray) -> np.ndarray:
        """One decode step over every slot; returns per-slot argmax."""
        logits, self.cache = self._decode_fn(
            self.params, self.cache, jnp.asarray(last_tokens))
        return np.asarray(jnp.argmax(logits[:, -1, :], axis=-1)
                          .astype(jnp.int32))

    # -- paged-pool device ops ---------------------------------------------------
    def fork_page(self, slot: int, j: int, src: int, dst: int) -> None:
        """Copy-on-write fork: copy physical page ``src`` → ``dst`` in every
        paged pool and point this slot's table entry ``j`` at the copy."""
        def fork(d):
            kp, vp = d["k_pages"], d["v_pages"]
            table = d["page_table"]
            if kp.ndim == 4:
                kp = kp.at[dst].set(kp[src])
                vp = vp.at[dst].set(vp[src])
                table = table.at[slot, j].set(dst)
            else:                               # leading scan (layers) dim
                kp = kp.at[:, dst].set(kp[:, src])
                vp = vp.at[:, dst].set(vp[:, src])
                table = table.at[:, slot, j].set(dst)
            ro = d["page_ro"].at[..., dst].set(False)
            return dict(d, k_pages=kp, v_pages=vp, page_table=table,
                        page_ro=ro)

        self.cache = _map_paged(self.cache, fork)

    def set_pages_ro(self, pages, value: bool) -> None:
        """(Un)write-protect physical pages device-side: decode scatters at
        an RO page are dropped like overflow writes (defense in depth — the
        pool manager forks before any legitimate write reaches one)."""
        idx = jnp.asarray(list(pages), jnp.int32)

        def mark(d):
            return dict(d, page_ro=d["page_ro"].at[..., idx].set(value))

        self.cache = _map_paged(self.cache, mark)

    def park(self, slot: int) -> None:
        """Point a released slot's table rows at the parking page (its idle
        decode writes must never land on pages a later admission owns)."""
        from repro.serve import disagg

        self.cache = disagg.park_slot(self.cache, slot)

    # -- cache insertion ---------------------------------------------------------
    def _insert(self, full, one, slot, phys_pages, write_ok):
        """Insert the freshly prefilled 1-row cache ``one`` into slot ``slot``
        of the engine cache ``full`` (recursive walk; paged attention dicts
        scatter through the page table, everything else along the batch
        axis)."""
        if isinstance(full, dict):
            if "k_pages" in full:
                return self._insert_paged_attn(full, one, slot, phys_pages,
                                               write_ok)
            return {key: self._insert(full[key], one[key], slot, phys_pages,
                                      write_ok)
                    for key in full}
        if isinstance(full, list):
            return [self._insert(f, o, slot, phys_pages, write_ok)
                    for f, o in zip(full, one)]
        return _insert_row(full, one, slot, self.n_slots)

    def _insert_paged_attn(self, full, one, slot, phys_pages, write_ok):
        """Scatter a dense (1, S, KV, hd) prefill KV into the slot's physical
        pages and point the slot's page-table row at them.  Pages with
        ``write_ok=False`` are *shared* — the donor already holds their
        prefix KV — so their scatter is routed to the parking page while the
        table still maps them."""
        pt = self.page_tokens
        park = full["k_pages"].shape[-4] - 1
        dest = jnp.where(write_ok, phys_pages, park)

        def repage_scatter(pool, dense):
            *lead, _, s, kv, hd = dense.shape
            d = dense.reshape(*lead, s // pt, pt, kv, hd).astype(pool.dtype)
            if pool.ndim == 4:
                return pool.at[dest].set(d)
            return pool.at[:, dest].set(d)   # leading scan dim

        table, pos = full["page_table"], full["pos"]
        if table.ndim == 2:
            table = table.at[slot].set(phys_pages)
            pos = pos.at[slot].set(one["pos"][0])
        else:
            table = table.at[:, slot].set(phys_pages)
            pos = pos.at[:, slot].set(one["pos"][:, 0])
        return dict(
            full,
            k_pages=repage_scatter(full["k_pages"], one["k"]),
            v_pages=repage_scatter(full["v_pages"], one["v"]),
            page_table=table,
            pos=pos,
        )


class ServeEngine:
    """Greedy-decoding continuous-batching engine over ``n_slots`` slots —
    the facade wiring scheduler, KV pool manager, and executor together."""

    def __init__(self, model, params, *, n_slots: int, max_seq: int,
                 enc_len: int = 0, paged_kv: bool = False,
                 page_tokens: int = 16, policy: str = "continuous",
                 prefix_share: bool = False, kv_pages: int | None = None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.paged_kv = paged_kv
        if prefix_share and not paged_kv:
            raise ValueError("prefix_share=True requires paged_kv=True "
                             "(sharing happens on the physical page pool)")
        self.prefix_share = prefix_share
        self.executor = Executor(model, params, n_slots=n_slots,
                                 max_seq=max_seq, enc_len=enc_len,
                                 paged_kv=paged_kv, page_tokens=page_tokens)
        if paged_kv:
            self.page_tokens = page_tokens
            self.pages_per_slot = max_seq // page_tokens
            n_pages = n_slots * self.pages_per_slot
            if kv_pages is not None:
                if not self.pages_per_slot <= kv_pages <= n_pages:
                    raise ValueError(
                        f"kv_pages={kv_pages} must be between pages_per_slot"
                        f"={self.pages_per_slot} and the device pool size "
                        f"{n_pages}")
                n_pages = kv_pages
            self.pool = KVPoolManager(n_pages)
            self.slot_pages: dict[int, list[int]] = {}
            self._ro_pages: set[int] = set()
        self.scheduler = Scheduler(n_slots, policy)
        self.slot_free = [True] * n_slots
        self.slot_req: dict[int, Request] = {}
        self.slot_generated: dict[int, list] = {}
        self.slot_pos: dict[int, int] = {}
        self.slot_entry: dict[int, object] = {}
        self.done: list[Completion] = []
        self._last_tokens = np.zeros((n_slots, 1), np.int32)
        self._tick = 0
        self._incomplete = 0
        self.max_live = 0

    # -- compat views ------------------------------------------------------------
    @property
    def cache(self):
        return self.executor.cache

    @property
    def pending(self) -> list[Request]:
        return [e.req for e in self.scheduler.pending_entries()]

    @property
    def allocator(self):
        """The pool layer (old name for the paged engine's allocator)."""
        return self.pool

    # -- public API --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.max_seq:
            raise ValueError("prompt longer than max_seq")
        self.scheduler.submit(req, tick=self._tick,
                              t_submit=time.perf_counter())

    def step(self) -> None:
        """One engine tick: admit per the policy, then one decode step."""
        self._admit()
        if self.slot_req:
            if self.paged_kv and self.prefix_share:
                self._cow_tick()
            nxt = self.executor.decode(self._last_tokens)
            for slot in list(self.slot_req):
                tok = int(nxt[slot])
                self.slot_generated[slot].append(tok)
                self.slot_pos[slot] += 1
                self._last_tokens[slot, 0] = tok
                self._finish_if_ended(slot)
        self._tick += 1

    def run(self, max_ticks: int = 10_000, *,
            strict: bool = False) -> list[Completion]:
        """Drive ticks until every submitted request completes or
        ``max_ticks`` is exhausted.

        On exhaustion the still-in-flight work is **not** silently dropped:
        each live slot yields a ``Completion(finished=False)`` with its
        partial tokens, each still-queued request one with no tokens, and
        ``stats()['incomplete']`` counts them — or, under ``strict=True``,
        a ``RuntimeError`` names the unfinished rids.  Engine state is left
        intact either way, so ``run()`` can be called again to continue."""
        ticks = 0
        while ((self.scheduler.pending_count or self.slot_req)
               and ticks < max_ticks):
            self.step()
            ticks += 1
        live = [(slot, self.slot_req[slot]) for slot in sorted(self.slot_req)]
        queued = self.scheduler.pending_entries()
        self._incomplete = len(live) + len(queued)
        if self._incomplete and strict:
            rids = [r.rid for _, r in live] + [e.req.rid for e in queued]
            raise RuntimeError(
                f"run(max_ticks={max_ticks}) exhausted with "
                f"{self._incomplete} request(s) unfinished (rids {rids}) — "
                "raise max_ticks, or strict=False for explicit incomplete "
                "completions")
        out = list(self.done)
        for slot, req in live:
            e = self.slot_entry.get(slot)
            out.append(Completion(req.rid, list(self.slot_generated[slot]),
                                  False, e.arrival if e else 0, self._tick))
        for e in queued:
            out.append(Completion(e.req.rid, [], False, e.arrival,
                                  self._tick))
        return out

    def stats(self) -> dict:
        """Engine health across all three layers."""
        out = {"completed": len(self.done),
               "pending": self.scheduler.pending_count,
               "live_slots": len(self.slot_req), "paged_kv": self.paged_kv,
               "policy": self.scheduler.policy,
               "submitted": self.scheduler.submitted,
               "admitted": self.scheduler.admitted,
               "ticks": self._tick, "incomplete": self._incomplete,
               "max_live": self.max_live}
        if self.paged_kv:
            out.update(pages_allocated=self.pool.allocs,
                       pages_freed=self.pool.frees,
                       pages_free=self.pool.n_free,
                       page_tokens=self.page_tokens,
                       pages_shared=self.pool.shared_maps,
                       cow_copies=self.pool.cow_copies,
                       cow_debt=self.pool.cow_debt)
        return out

    # -- internals --------------------------------------------------------------
    def _finish_if_ended(self, slot: int) -> bool:
        """Complete-and-release ``slot`` iff its latest token terminates the
        request (EOS, token budget, or cache full) — the single termination
        predicate shared by the decode loop and admission-time prefill."""
        req = self.slot_req[slot]
        gen = self.slot_generated[slot]
        ended = (gen[-1] == req.eos_id or
                 len(gen) >= req.max_new_tokens or
                 self.slot_pos[slot] >= self.max_seq - 1)
        if ended:
            e = self.slot_entry.get(slot)
            self.done.append(Completion(req.rid, gen, True,
                                        e.arrival if e else 0, self._tick))
            self._release(slot)
        return ended

    def _admit(self) -> None:
        """Admit what the scheduler selects, until it selects nothing (an
        admission-time completion frees its slot within the tick, so the
        loop re-asks — preserving the old engine's immediate reuse)."""
        while True:
            n_free = sum(self.slot_free)
            entries = self.scheduler.select(n_free, live=len(self.slot_req),
                                            tick=self._tick)
            if not entries:
                return
            for idx, entry in enumerate(entries):
                slot = self.slot_free.index(True)
                if not self._admit_one(entry, slot):
                    # pool pressure: hand this and the rest back, front of
                    # queue, original order — retry next tick
                    for e in reversed(entries[idx:]):
                        self.scheduler.requeue(e)
                    return

    def _admit_one(self, entry, slot: int) -> bool:
        """Prefill one selected request into ``slot``.  Returns False (no
        state changed, entry must be requeued) when the pool cannot back it
        fork-safely."""
        req = entry.req
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        if self.paged_kv:
            shared, shared_rw = ([], [])
            if self.prefix_share:
                shared, shared_rw = self._share_plan(req)
            n_fresh = self.pages_per_slot - len(shared) - len(shared_rw)
            if not self.pool.can_admit(n_fresh, len(shared_rw)):
                return False
            fresh = self.pool.alloc(n_fresh)
            if shared:
                self.pool.share_pages(shared)
            if shared_rw:
                self.pool.share_pages(shared_rw, writable=True)
            phys = shared + shared_rw + fresh
            self.slot_pages[slot] = phys
            write_ok = np.ones(self.pages_per_slot, bool)
            write_ok[:len(shared) + len(shared_rw)] = False
            newly_ro = [p for p in shared + shared_rw
                        if self.pool.refcount_of(p) >= 2]
            if newly_ro:
                self.executor.set_pages_ro(newly_ro, True)
                self._ro_pages.update(newly_ro)
            phys_arg = jnp.asarray(phys, jnp.int32)
            ok_arg = jnp.asarray(write_ok)
        else:
            phys_arg = jnp.zeros((0,), jnp.int32)
            ok_arg = jnp.zeros((0,), bool)
        first = self.executor.prefill(tokens, slot, phys_arg, ok_arg)
        self.slot_free[slot] = False
        self.slot_req[slot] = req
        self.slot_generated[slot] = [first]
        self.slot_pos[slot] = len(req.prompt) + 1
        self.slot_entry[slot] = entry
        self.max_live = max(self.max_live, len(self.slot_req))
        # the prefill token can already terminate the request (EOS, or
        # max_new_tokens=1, or the cache is full): complete-and-release
        # here, or the slot decodes a spurious extra step — and in paged
        # mode holds its KV pages — for a full extra tick
        if self._finish_if_ended(slot):
            return True
        self._last_tokens[slot, 0] = first
        return True

    def _share_plan(self, req: Request) -> tuple[list[int], list[int]]:
        """Find the live donor with the longest common prompt prefix and
        split its pages into (immutably shared, writable/COW shared).

        Full pages entirely inside the common prefix hold bit-identical KV
        for both sequences and are shared read-only.  The partial page at
        the prefix boundary is shared copy-on-write only when the new
        prompt ends exactly at the prefix — otherwise the new prefill must
        write that page's tail, which would need a fork *at admission*;
        allocating fresh is simpler and equally correct."""
        prompt = [int(t) for t in req.prompt]
        best_c, donor = 0, None
        for slot, dreq in self.slot_req.items():
            if slot not in self.slot_pages:
                continue
            dp = dreq.prompt
            c = 0
            for a, b in zip(prompt, dp):
                if a != int(b):
                    break
                c += 1
            if c > best_c:
                best_c, donor = c, slot
        if donor is None:
            return [], []
        pt = self.page_tokens
        n_full = min(best_c // pt, self.pages_per_slot)
        shared = [self.slot_pages[donor][j] for j in range(n_full)]
        shared_rw = []
        if (best_c % pt and len(prompt) == best_c
                and n_full < self.pages_per_slot):
            shared_rw = [self.slot_pages[donor][n_full]]
        return shared, shared_rw

    def _cow_tick(self) -> None:
        """Fork any shared page a live slot is about to write.

        The write position this tick is ``slot_pos - 1`` (prefill leaves
        ``slot_pos`` one ahead of the cache position).  If its page is
        still mapped by another sequence, the pool moves this holder onto a
        fresh page and the executor copies contents + remaps the table —
        before the decode scatter, so no write ever lands on a shared
        page."""
        for slot in list(self.slot_req):
            pages = self.slot_pages.get(slot)
            if not pages:
                continue
            wpos = self.slot_pos[slot] - 1
            j = wpos // self.page_tokens
            if j >= self.pages_per_slot:
                continue               # cache full: the write is dropped
            p = pages[j]
            if self.pool.refcount_of(p) <= 1:
                if p in self._ro_pages:     # last co-holder is gone
                    self.executor.set_pages_ro([p], False)
                    self._ro_pages.discard(p)
                continue
            new, _ = self.pool.cow_write(p)
            self.executor.fork_page(slot, j, p, new)
            pages[j] = new
            if self.pool.refcount_of(p) <= 1 and p in self._ro_pages:
                self.executor.set_pages_ro([p], False)
                self._ro_pages.discard(p)

    def _release(self, slot: int) -> None:
        self.slot_free[slot] = True
        del self.slot_req[slot]
        del self.slot_generated[slot]
        del self.slot_pos[slot]
        self.slot_entry.pop(slot, None)
        if self.paged_kv and slot in self.slot_pages:
            # park the row before its pages go back to the free list: idle
            # rows keep scattering per-step KV, and those writes must never
            # land on pages a later admission may own
            self.executor.park(slot)
            dropped = self.pool.release(self.slot_pages.pop(slot))
            ro_clear = [p for p in dropped if p in self._ro_pages]
            if ro_clear:
                self.executor.set_pages_ro(ro_clear, False)
                self._ro_pages.difference_update(ro_clear)


__all__ = ["ServeEngine", "Executor", "Request", "Completion"]
