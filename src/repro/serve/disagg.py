"""Disaggregated prefill→decode serving on the RMA substrate.

This module is the application-scale composition of the paper's proposals —
the serving data plane the ROADMAP asks for, built entirely out of the
primitives the RMA layer already demonstrates in isolation:

* **P5 (memory handles)** — decode engines expose their KV pool as a
  :class:`~repro.serve.paged.PagedKVWindow`; page descriptors are exchanged
  once at allocation and every prefill push is direct RDMA through the
  handle, zero lookup overhead (paper §4.2, Fig. 12).  The lifetime
  guarantee makes eviction safe: a push or read racing a ``free_page`` is
  dropped/zero-masked and *counted*, never corrupts reused memory.
* **P2 (ordered sequences)** — a sequence's pages are issued back-to-back on
  one ordered channel and the per-sequence **doorbell** (``put_signal``)
  chains behind the last page: one data phase per page, one flush epoch per
  batch, no per-page acks (paper Listing 2 at serving scale; foMPI's
  notified-access recipe).
* **P3 (op intrinsics)** — decode **admission** is a remote atomic: lanes
  claim slot tickets with ``fetch_op`` counters on a small control window
  (same_op="sum" declared, so the doorbell flag lowers to the 1-phase
  NIC-atomic path).
* **P1 × P4 (scoped flushes on dup'd views)** — every decode lane runs on
  its own issue stream of the shared substrate and completes with
  *thread-scoped* flush epochs, so lanes never serialize each other's
  completion; per-use configs ride zero-copy dup'd views of the one pool.

Layout of the control window (int32 words)::

    [ticket | meta(seq 0), bell(seq 0) | meta(seq 1), bell(seq 1) | ...]

``ticket`` is the fetch_op admission counter; per sequence, ``meta`` carries
the page count of the pushed sequence and ``bell`` is the doorbell flag the
consumer polls.

The SPMD functions here run inside ``shard_map`` (prefill devices push to
decode devices over a mesh axis).  The host-side pieces —
:class:`PageAllocator` and :func:`paginate_cache` — wire the same page-table
discipline into the single-process :class:`~repro.serve.engine.ServeEngine`
(``paged_kv=True``), so the engine's KV cache *is* the decode-side pool
layout a disaggregated deployment would receive pushes into.

Run the 8-fake-device round-trip demo (prefill→push→doorbell→admission→
decode through the handle path) with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.serve.disagg
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.rma import (
    SCOPE_THREAD,
    Window,
    WindowConfig,
    put_signal,
)
from repro.serve.paged import PagedKVWindow, PageSpec
from repro.serve.scheduler import Scheduler

Array = jax.Array

#: Control-window word 0: the fetch_op admission ticket counter.
CTRL_TICKET = 0


def ctrl_meta_offset(seq: int) -> int:
    """Word carrying sequence ``seq``'s pushed page count."""
    return 1 + 2 * seq


def ctrl_flag_offset(seq: int) -> int:
    """Sequence ``seq``'s doorbell flag word."""
    return 2 + 2 * seq


def ctrl_size(n_seqs: int) -> int:
    return 1 + 2 * n_seqs


def make_control_window(n_seqs: int, axis: str, axis_size: int, *,
                        n_lanes: int = 2) -> Window:
    """The decode-side control window: ticket counter + per-sequence
    (meta, doorbell) word pairs.

    Declared ``same_op="sum"`` so doorbell flags route through the
    accumulate engine's 1-phase intrinsic path, ``order=True`` so a doorbell
    chains behind its sequence's data with no intermediate flush, and
    thread scope with one issue stream per decode lane (P1 × P4)."""
    buf = jnp.zeros((ctrl_size(n_seqs),), jnp.int32)
    cfg = WindowConfig(scope=SCOPE_THREAD, order=True, max_streams=n_lanes,
                      same_op="sum", accumulate_ops=("sum",))
    return Window.allocate(buf, axis, axis_size, cfg)


# ---------------------------------------------------------------------------
# SPMD data plane: push / doorbell / admission
# ---------------------------------------------------------------------------


def push_sequence(pool: PagedKVWindow, ctrl: Window, seq: int,
                  pages, kvs, perm, *, lane: int = 0,
                  ) -> tuple[PagedKVWindow, Window]:
    """Prefill side: push one sequence's filled pages into the decode pool
    and ring its doorbell.

    The pages ride a single batched :meth:`PagedKVWindow.push_pages` (a
    compiled-plan replay: one ordered view, one thread-scoped flush epoch
    for the whole batch); the doorbell is a ``put_signal`` on the control
    window — the
    page count lands in the sequence's meta word and the flag accumulate
    chains behind it on the same ordered channel.  The control window is a
    *different* substrate than the pool, so the doorbell is sequenced
    ``after=`` the pool lane's post-flush completion token: it cannot land
    before the batch's flush epoch completes — notified access, a consumer
    that observes ``bell ≠ 0`` may read the pages with no flush of its own.
    Everything is issued on ``lane``'s stream, so concurrent sequences on
    different lanes neither share a flush epoch nor serialize."""
    pool = pool.push_pages(pages, kvs, perm, stream=lane)
    ctrl = put_signal(ctrl, jnp.asarray([len(pages)], jnp.int32), perm,
                      data_offset=ctrl_meta_offset(seq),
                      flag_offset=ctrl_flag_offset(seq), stream=lane,
                      after=pool.window.completion_token(lane))
    return pool, ctrl


def claim_slot(ctrl: Window, perm, *, n_slots: int, lane: int = 0,
               ) -> tuple[Window, Array, Array]:
    """Decode admission: atomically claim the next ticket on the target's
    control window (``MPI_Fetch_and_op`` on the counter word) and map it to
    a decode slot.  Returns ``(ctrl, ticket, slot)``."""
    ctrl, old = ctrl.fetch_op(jnp.ones((1,), jnp.int32), perm, op="sum",
                              offset=CTRL_TICKET, stream=lane)
    ticket = old[0]
    return ctrl, ticket, jnp.mod(ticket, n_slots)


def claim_slots(ctrl: Window, perm, scheduler, *, live: int = 0,
                lane: int = 0, max_claims: int | None = None,
                source: str | None = None) -> tuple[Window, list, list]:
    """Policy-driven decode admission: claim up to the scheduler's ticket
    budget for this tick (:meth:`repro.serve.scheduler.Scheduler.
    ticket_window` — 0 under ``static`` policy while sequences are live,
    the free-slot count otherwise) via remote fetch_op, mapping each ticket
    through :meth:`~repro.serve.scheduler.Scheduler.slot_for_ticket`.

    ``source`` names the claiming worker: its claim count is registered
    host-side (:meth:`~repro.serve.scheduler.Scheduler.note_claims`) so
    the tickets count against later windows until the worker binds them to
    live sequences (``consume_claims``) — or is evicted, when
    ``release_claims`` returns them (the elastic path; a leaked claim
    would stall admission forever).  The ticket *values* stay device-side
    (they are tracers inside the SPMD region); only the count is tracked.

    Returns ``(ctrl, tickets, slots)`` — possibly empty lists when the
    policy grants no admissions."""
    budget = scheduler.ticket_window(live)
    if max_claims is not None:
        budget = min(budget, max_claims)
    tickets, slots = [], []
    for _ in range(budget):
        ctrl, old = ctrl.fetch_op(jnp.ones((1,), jnp.int32), perm, op="sum",
                                  offset=CTRL_TICKET, stream=lane)
        tickets.append(old[0])
        slots.append(scheduler.slot_for_ticket(old[0]))
    if source is not None:
        scheduler.note_claims(len(tickets), source=source)
    return ctrl, tickets, slots


def read_doorbell(ctrl: Window, seq: int) -> tuple[Array, Array]:
    """Consumer-side poll: ``(flag, page_count)`` for sequence ``seq`` —
    local reads of the control window, no communication."""
    return ctrl.buffer[ctrl_flag_offset(seq)], ctrl.buffer[ctrl_meta_offset(seq)]


def pool_stats(pool: PagedKVWindow) -> dict[str, Array]:
    """The disagg engine's pool-health stats, aggregated across every
    handle-path transfer: live page count and the P5 stale-handle drop
    counter (non-zero ⇒ a peer pushed or read through a freed page)."""
    return {
        "live_pages": pool.live.sum().astype(jnp.int32),
        "err_count": pool.err_count,
    }


# ---------------------------------------------------------------------------
# Host side: the page allocator + paged-cache plumbing for ServeEngine
# ---------------------------------------------------------------------------


class PageAllocator:
    """Host-side FIFO free-list over the decode pool's physical pages.

    FIFO (not LIFO) so freed pages are reused as late as possible — maximum
    pressure on the stale-handle guarantee in tests and the most grace for
    in-flight transfers in a real deployment."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free = list(range(n_pages))
        self.allocs = 0
        self.frees = 0

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted: need {n} pages, "
                f"{len(self._free)}/{self.n_pages} free")
        pages, self._free = self._free[:n], self._free[n:]
        self.allocs += n
        return pages

    def free(self, pages) -> None:
        self._free.extend(pages)
        self.frees += len(pages)

    @property
    def n_free(self) -> int:
        return len(self._free)


def _is_gqa_cache(d) -> bool:
    return isinstance(d, dict) and set(d) == {"k", "v", "pos"}


def paginate_cache(cache, page_tokens: int):
    """Convert every dense GQA KV leaf ``{k, v, pos}`` of a stack cache into
    the pooled page layout ``{k_pages, v_pages, page_table, pos}``.

    Dense ``k``/``v`` leaves of shape ``(…, B, S, KV, hd)`` become physical
    pools of ``B·S/pt`` allocatable pages **plus one parking page**; every
    page-table entry starts pointing at the parking page, and the engine's
    :class:`PageAllocator` (which hands out ids ``0 … B·S/pt − 1``) wires
    rows to real pages at slot admission.  The parking page matters: idle
    and released decode rows still scatter their (discarded) per-step KV
    through the table, and parking those writes on a page no allocation can
    ever own is what keeps them from corrupting a live slot's pages.
    Leaves that are not self-attention KV (cross-attention, MLA, SSM state,
    the step counter) pass through unchanged, so hybrid stacks page only
    what pages.

    The ``page_ro`` leaf is the pool's per-page write protection: the
    engine sets it for pages mapped by more than one sequence (COW prefix
    sharing), and the decode scatter in ``models/attention.py`` drops
    writes routed at a protected page exactly like overflow writes.  The
    parking page is never protected.

    The ``page_hot`` leaf is the pool's per-page **residency** bit (the
    tiered engine clears it for pages demoted to the host tier): the paged
    gather reroutes table entries at a non-hot page to the parking page and
    the scatter drops writes at one — defense in depth mirroring
    ``page_ro``, so a residency-bookkeeping bug reads zeros instead of a
    reclaimed page's bytes.  Everything starts hot (an untier'd engine
    never clears it), and the parking page is always hot."""
    if _is_gqa_cache(cache):
        k = cache["k"]
        *lead, b, s, kv, hd = k.shape
        if s % page_tokens:
            raise ValueError(f"max_seq={s} not divisible by "
                             f"page_tokens={page_tokens}")
        pages_per_row = s // page_tokens
        n_alloc = b * pages_per_row        # the allocator's page ids
        def repage(x):
            pool = x.reshape(*lead, n_alloc, page_tokens, kv, hd)
            park = jnp.zeros((*lead, 1, page_tokens, kv, hd), pool.dtype)
            return jnp.concatenate([pool, park], axis=len(lead))
        return {
            "k_pages": repage(k),
            "v_pages": repage(cache["v"]),
            "page_table": jnp.full((*lead, b, pages_per_row), n_alloc,
                                   jnp.int32),
            "page_ro": jnp.zeros((*lead, n_alloc + 1), bool),
            "page_hot": jnp.ones((*lead, n_alloc + 1), bool),
            "pos": cache["pos"],
        }
    if isinstance(cache, dict):
        return {key: paginate_cache(val, page_tokens) for key, val in cache.items()}
    if isinstance(cache, list):
        return [paginate_cache(val, page_tokens) for val in cache]
    return cache


def park_slot(cache, slot: int):
    """Point a released slot's page-table rows back at the parking page and
    rewind its position counter — after this, the slot's idle decode writes
    land on the parking page and its old (now freed, maybe re-allocated)
    pages are never touched again."""
    if isinstance(cache, dict):
        if "k_pages" in cache:
            table, pos = cache["page_table"], cache["pos"]
            park = cache["k_pages"].shape[-4] - 1   # the extra page
            if table.ndim == 2:
                table = table.at[slot].set(park)
                pos = pos.at[slot].set(0)
            else:
                table = table.at[:, slot].set(park)
                pos = pos.at[:, slot].set(0)
            return dict(cache, page_table=table, pos=pos)
        return {key: park_slot(val, slot) for key, val in cache.items()}
    if isinstance(cache, list):
        return [park_slot(val, slot) for val in cache]
    return cache


# ---------------------------------------------------------------------------
# The round-trip demo (8 fake devices): prefill→push→doorbell→admit→decode
# ---------------------------------------------------------------------------

N_DEMO_DEV = 8


def demo_round_trip(n_seqs: int = 2, pages_per_seq: int = 2,
                    n_lanes: int = 2, verbose: bool = True,
                    policy: str = "continuous") -> dict:
    """Drive one full disaggregated round trip across a ring of devices.

    Every device plays both roles (SPMD): as a *prefill* worker it fills
    ``n_seqs`` sequences' pages and pushes them into its ring successor's
    pool through memory handles, ringing one doorbell per sequence; as a
    *decode* worker it receives pushes from its predecessor, claims
    admission tickets with remote fetch_op, reads the doorbells/meta words
    and decodes (reads) the pushed pages — plus one stale-handle read after
    an eviction to show the P5 read guarantee end to end."""
    from jax.sharding import PartitionSpec as P

    from repro import compat

    n = N_DEMO_DEV
    if len(jax.devices()) < n:
        raise SystemExit(f"demo needs {n} devices "
                         "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    mesh = compat.make_mesh((n,), ("x",))
    perm = [(i, (i + 1) % n) for i in range(n)]
    spec = PageSpec(page_tokens=4, kv_heads=2, head_dim=8,
                    n_pages=n_seqs * pages_per_seq + 1)

    def scenario(_):
        pool = PagedKVWindow.create(spec, "x", n, dtype=jnp.float32)
        ctrl = make_control_window(n_seqs, "x", n, n_lanes=n_lanes)
        # decode side: allocate + register the pages each sequence will land
        # in (this is the once-per-allocation handle exchange of P5)
        for p in range(n_seqs * pages_per_seq):
            pool = pool.alloc_page(p)
        # prefill side: fill pages locally, push each sequence on its lane
        for s in range(n_seqs):
            pages = [s * pages_per_seq + j for j in range(pages_per_seq)]
            kvs = [jnp.full((2, spec.page_tokens, spec.kv_heads, spec.head_dim),
                            1.0 + s + 0.25 * j, jnp.float32)
                   for j in range(pages_per_seq)]
            pool, ctrl = push_sequence(pool, ctrl, s, pages, kvs, perm,
                                       lane=s % n_lanes)
        for lane in range(min(n_lanes, n_seqs)):
            ctrl = ctrl.flush(stream=lane)        # thread-scoped: per lane
        # decode admission: the scheduler policy grants each lane's ticket
        # budget (claim_slots), claimed with remote atomics
        sched = Scheduler(n_seqs, policy)
        tickets = []
        for lane in range(n_lanes):
            ctrl, ts, _slots = claim_slots(ctrl, perm, sched, live=0,
                                           lane=lane, max_claims=1)
            ctrl = ctrl.flush(stream=lane)
            tickets.extend(ts)
        # decode: doorbells + page contents pushed by the ring predecessor
        bells = [read_doorbell(ctrl, s) for s in range(n_seqs)]
        vals = [pool.read_page(s * pages_per_seq)[0, 0, 0, 0]
                for s in range(n_seqs)]
        # eviction: free sequence 0's first page; a read through the old
        # handle must come back zero-masked and counted, never reused memory
        stale_handle = pool.handles[0]
        pool = pool.free_page(0)
        from repro.core.rma import win_from_memhandle
        mhw = win_from_memhandle(pool.window, stale_handle)
        mhw, stale = mhw.get(perm, offset=0, size=4)
        stats = pool_stats(pool)
        out = jnp.concatenate([
            jnp.stack(vals),
            jnp.stack([b[0] for b in bells]).astype(jnp.float32),
            jnp.stack([b[1] for b in bells]).astype(jnp.float32),
            jnp.stack(tickets).astype(jnp.float32),
            stale[:4].astype(jnp.float32),
            (stats["err_count"] + mhw.err_count)[None].astype(jnp.float32),
            stats["live_pages"][None].astype(jnp.float32),
        ])
        return out[None]

    g = jax.jit(compat.shard_map(scenario, mesh=mesh, in_specs=P("x"),
                                 out_specs=P("x"), check_vma=False))
    import numpy as np
    out = np.asarray(g(jnp.zeros((n, 1))))
    k = n_seqs
    vals, bells, metas = out[:, :k], out[:, k:2 * k], out[:, 2 * k:3 * k]
    tickets = out[:, 3 * k:3 * k + n_lanes]
    stale = out[:, 3 * k + n_lanes:3 * k + n_lanes + 4]
    errs = out[:, 3 * k + n_lanes + 4]
    live = out[:, 3 * k + n_lanes + 5]
    checks = {
        "pages_landed": bool(np.allclose(vals, [1.0 + s for s in range(k)])),
        "doorbells": bool((bells == 1.0).all()),
        "meta_page_counts": bool((metas == pages_per_seq).all()),
        "tickets": bool((tickets == np.arange(n_lanes)).all()),
        "stale_read_masked": bool((stale == 0.0).all()),
        "stale_read_counted": bool((errs == 1.0).all()),
        "live_pages": bool((live == k * pages_per_seq - 1).all()),
    }
    if verbose:
        print(f"[disagg] {k} seqs x {pages_per_seq} pages pushed over "
              f"{n}-device ring on {n_lanes} lanes ({policy} admission)")
        for name, ok in checks.items():
            print(f"[disagg]   {name}: {'OK' if ok else 'FAIL'}")
    if not all(checks.values()):
        raise SystemExit(f"disagg round-trip failed: {checks}")
    return checks


if __name__ == "__main__":
    demo_round_trip()
    print("DISAGG ROUND-TRIP OK")


__all__ = [
    "CTRL_TICKET",
    "ctrl_meta_offset",
    "ctrl_flag_offset",
    "ctrl_size",
    "make_control_window",
    "push_sequence",
    "claim_slot",
    "claim_slots",
    "read_doorbell",
    "pool_stats",
    "PageAllocator",
    "paginate_cache",
    "park_slot",
    "demo_round_trip",
]
