"""Paged KV-cache as a dynamic RMA window — the serving-side use of P5.

The serving engine's KV pool is the TPU analogue of the paper's dynamic
window: pages (fixed-size token blocks) are *attached* segments of a
process-local pool, allocated and freed as sequences come and go — exactly
the "communication requirements change over time" motivation of paper §4.

Access paths, mirroring the paper's measurement taxonomy:

* ``query``    — the page's registration (offset/epoch) is looked up
  remotely per access (dynamic window without handles; Fig. 3b),
* ``memhandle`` — page descriptors are exchanged once at allocation; decode-
  time accesses are direct RDMA with zero lookup overhead (P5).  A page's
  handle dies with ``free_page`` (epoch bump) — use-after-free is dropped
  and counted, never corrupts (the life-time guarantee).
* ``accumulate_page`` — in-place remote page updates (running KV stats,
  correction deltas, counters) through the op-specialized accumulate engine
  on a same-op dup'd view (paper §2.3 hints × P4), addressed via the page's
  memory handle.

A disaggregated prefill→decode deployment ships page handles instead of page
contents; ``benchmarks.put_latency`` quantifies the per-access win.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.rma import (
    DynamicWindow,
    WindowConfig,
    memhandle_create,
    memhandle_release,
    win_from_memhandle,
)

Array = jax.Array

_TRANSFER_PLANS: dict[tuple, object] = {}


def transfer_plan(pool_pages: int, pages: tuple, page_elems: int, dtype,
                  perm: tuple, stream: int = 0, *,
                  naive_flush: bool = False, topology=None,
                  backend: str = "rma"):
    """Build (or fetch from the build-once cache) the compiled page-push
    schedule: one :meth:`RmaPlan.put_handle` per page on the batch's ordered
    stream, one exit flush epoch — 2 phases per page (payload + handle
    header) + 2 for the epoch, never a per-page ack.

    ``topology``: the declared host factorization (see
    ``repro.core.rma.Topology``).  A push whose ``perm`` stays on one host
    (e.g. prefill and decode pools co-located) is classified into the
    shared-memory tier — same 2-phase pages, but the exit epoch drains
    nothing.  Part of the cache key: a pool re-created under a different
    factorization never replays the old schedule.

    ``backend``: lowering target for :meth:`RmaPlan.compile`.  Page pushes
    record no collective macro, so ``"auto"``/``"gspmd"`` resolve to the
    substrate schedule; ``"interpret"`` compiles but cannot execute (the
    handle path needs live registration state)."""
    from repro.core.rma.plan import RmaPlan
    from repro.core.rma.topology import topology_fingerprint

    if backend == "auto":
        backend = "rma"        # no macro to ever pick gspmd for
    dt = jnp.dtype(dtype)
    key = (pool_pages, tuple(pages), page_elems, dt.name, perm, stream,
           naive_flush, topology_fingerprint(topology), backend)
    if key in _TRANSFER_PLANS:
        return _TRANSFER_PLANS[key]
    plan = RmaPlan(f"transfer_pages[{len(pages)}]", topology=topology)
    plan.window("pool", scope="thread", order=True, max_streams=stream + 1,
                dtype=dt, exit_epoch=True)
    plan.bind("handles", (pool_pages, 4), jnp.int32)
    for i, page in enumerate(pages):
        plan.bind(f"kv{i}", (page_elems,), dt)
        plan.put_handle("pool", f"kv{i}",
                        lambda env, p=page: env["handles"][p], perm,
                        slot=page, stream=stream, shape=(page_elems,),
                        dtype=dt, label=f"page{page}")
    compiled = plan.compile(naive_flush=naive_flush, backend=backend)
    _TRANSFER_PLANS[key] = compiled
    return compiled


@dataclasses.dataclass(frozen=True)
class PageSpec:
    page_tokens: int          # tokens per page
    kv_heads: int
    head_dim: int
    n_pages: int              # pool capacity

    @property
    def page_elems(self) -> int:
        return self.page_tokens * self.kv_heads * self.head_dim * 2  # K and V


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVWindow:
    """Fixed-capacity page pool exposed as a dynamic window.

    ``window.buffer`` is the flat pool; page *p* occupies
    ``[p·page_elems, (p+1)·page_elems)``.  ``page_map`` (host side) tracks
    free pages; ``handles`` holds each live page's memory handle (what a
    remote decode engine would receive).

    ``err_count`` aggregates the P5 stale-handle drops observed across every
    handle-path transfer issued through this pool (put / get / accumulate /
    batched transfers) — the per-transfer ``MemhandleWindow`` counters would
    otherwise die with their throwaway view.  The disagg engine surfaces it
    in its serving stats; a non-zero value means a peer pushed (or read)
    through a freed page's handle.
    """

    window: DynamicWindow
    handles: Array            # (n_pages, 4) int32 — live pages' memhandles
    live: Array               # (n_pages,) bool
    spec: PageSpec
    err_count: Array = None   # () int32 — aggregated stale-handle violations

    def __post_init__(self):
        if self.err_count is None:
            self.err_count = jnp.zeros((), jnp.int32)

    def tree_flatten(self):
        return (self.window, self.handles, self.live, self.err_count), (self.spec,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux[0], children[3])

    def _replace(self, **kw) -> "PagedKVWindow":
        return dataclasses.replace(self, **kw)

    # -- construction ---------------------------------------------------------
    @classmethod
    def create(cls, spec: PageSpec, axis: str, axis_size: int,
               dtype=jnp.bfloat16, *, topology=None) -> "PagedKVWindow":
        pool = jnp.zeros((spec.n_pages * spec.page_elems,), dtype)
        win = DynamicWindow.create_dynamic(
            pool, axis, axis_size,
            WindowConfig(scope="thread", order=True, max_streams=4,
                         topology=topology),
            max_attach=spec.n_pages, am_slots=1, am_msg=1)
        return cls(
            window=win,
            handles=jnp.zeros((spec.n_pages, 4), jnp.int32),
            live=jnp.zeros((spec.n_pages,), bool),
            spec=spec,
        )

    # -- page lifecycle ---------------------------------------------------------
    def alloc_page(self, page: int) -> "PagedKVWindow":
        """Attach page ``page`` and create its memory handle (P5): local,
        no communication — the handle is what peers get."""
        s = self.spec
        win = self.window.attach(page, offset=page * s.page_elems,
                                 size=s.page_elems)
        mh = memhandle_create(win, page)
        return self._replace(window=win, handles=self.handles.at[page].set(mh),
                             live=self.live.at[page].set(True))

    def free_page(self, page: int) -> "PagedKVWindow":
        """Release through the substrate's consolidated lifetime machinery:
        ``memhandle_release`` invalidates the slot, bumps the traced epoch
        (stale remote writes are dropped and counted) and records the release
        in the dup family's flush queues, so statically-created handle
        windows for this page raise on use-after-free.

        Freeing a page that is not live (double free, or never allocated)
        raises with the page id: a second release would bump the epoch past
        the one outstanding handles were checked against and silently re-arm
        a dead slot.  The guard runs whenever liveness is concrete (eager
        host-side pool management); under a trace the liveness bit is a
        tracer and the epoch machinery remains the backstop."""
        import jax.core

        live = self.live[page] if 0 <= page < self.spec.n_pages else False
        if not isinstance(live, jax.core.Tracer) and not bool(live):
            raise ValueError(
                f"free_page({page}): page is not allocated "
                f"(double free, or never alloc_page'd)")
        win = memhandle_release(self.window, page)
        return self._replace(window=win, handles=self.handles.at[page].set(0),
                             live=self.live.at[page].set(False))

    # -- data paths ---------------------------------------------------------------
    def write_page_local(self, page: int, kv: Array) -> "PagedKVWindow":
        """Local fill (the prefill engine writing its own pool)."""
        s = self.spec
        buf = jax.lax.dynamic_update_slice_in_dim(
            self.window.buffer, kv.reshape(-1).astype(self.window.buffer.dtype),
            page * s.page_elems, axis=0)
        return self._replace(window=self.window._with(buffer=buf))

    def read_page(self, page: int) -> Array:
        s = self.spec
        flat = jax.lax.dynamic_slice_in_dim(
            self.window.buffer, page * s.page_elems, s.page_elems, axis=0)
        return flat.reshape(2, s.page_tokens, s.kv_heads, s.head_dim)

    def put_page_remote(self, page: int, kv: Array, perm,
                        stream: int = 0, *, order: bool = True,
                        ) -> "PagedKVWindow":
        """Disaggregated path: push a filled page into a peer's pool through
        its memory handle — one RDMA phase, no target involvement.

        The transfer runs on a **duplicated view** of the pool window (paper
        P4) carrying the per-transfer config (ordered channel, thread-scope
        completion) — same backing pool, same flush queues, zero copies —
        instead of re-allocating or disturbing the pool's own config."""
        xfer = self.window.dup_with_info(order=order, scope="thread")
        mhwin = win_from_memhandle(xfer, self.handles[page], slot=page)
        mhwin = mhwin.put(kv.reshape(-1), perm, stream=stream)
        mhwin = mhwin.flush(stream)
        parent = dataclasses.replace(mhwin.parent, config=self.window.config)
        return self._replace(window=parent,
                             err_count=self.err_count + mhwin.err_count)

    def accumulate_page(self, page: int, update: Array, perm, *,
                        op: str = "sum", offset: int = 0, stream: int = 0,
                        ) -> "PagedKVWindow":
        """In-place remote update of a live page — running KV statistics,
        speculative-decode correction deltas, visit counters — through the
        op-specialized accumulate engine.

        The update travels through a **dup'd view declaring single-op usage**
        (``same_op=op``, paper §2.3 hints × P4 dup): small updates on atomic-
        capable dtypes lower to the 1-phase NIC-atomic path, large ones to
        the tiled VPU bandwidth path — never the conservative generic path a
        hint-less accumulate would take.  Addressing goes through the page's
        memory handle (P5), so the target is not involved in the lookup."""
        view = self.window.dup_with_info(order=True, scope="thread",
                                         same_op=op, accumulate_ops=(op,))
        mhwin = win_from_memhandle(view, self.handles[page], slot=page)
        mhwin = mhwin.accumulate(update.reshape(-1), perm, op=op,
                                 offset=offset, stream=stream)
        mhwin = mhwin.flush(stream)
        parent = dataclasses.replace(mhwin.parent, config=self.window.config)
        return self._replace(window=parent,
                             err_count=self.err_count + mhwin.err_count)

    def push_pages(self, pages, kvs, perm, stream: int = 0, *,
                   backend: str = "rma") -> "PagedKVWindow":
        """Batched disaggregated push as a **declarative-plan replay**: the
        batch's schedule — every page issued back-to-back through its memory
        handle on one ordered stream, one thread-scoped flush epoch for the
        whole batch, no per-page acks — is planned once per (pages, shape)
        signature and cached; each call replays it with this step's handles
        and payloads.  ``pages`` must be static (Python ints): the per-page
        registration slots are part of the plan, which is what arms the P5
        trace-time use-after-release check on every replay."""
        compiled = transfer_plan(
            self.spec.n_pages, tuple(pages), self.spec.page_elems,
            self.window.buffer.dtype, tuple(tuple(p) for p in perm), stream,
            topology=self.window.config.topology, backend=backend)
        bindings = {"handles": self.handles}
        for i, kv in enumerate(kvs):
            bindings[f"kv{i}"] = kv.reshape(-1).astype(self.window.buffer.dtype)
        res = compiled.execute({"pool": self.window}, bindings)
        return self._replace(window=res.windows["pool"],
                             err_count=self.err_count + res.err_count)

    def transfer_pages(self, pages, kvs, perm, stream: int = 0,
                       ) -> "PagedKVWindow":
        """Batched disaggregated push: every page is issued back-to-back on
        one dup'd ordered view and a **single** thread-scoped flush epoch
        completes the whole batch — the pipelined put+signal shape of the
        cross-pod exchange, applied to KV pages.  ``pages`` must be static
        (Python ints): the per-page handles are resolved at trace time.

        .. deprecated:: kept as a thin wrapper over the plan-native
           :meth:`push_pages` (same numerics, same phase structure); emits a
           ``DeprecationWarning`` once per process."""
        from repro.core.rma.plan import warn_legacy_once

        warn_legacy_once("PagedKVWindow.transfer_pages",
                         "PagedKVWindow.push_pages (plan replay)")
        return self.push_pages(pages, kvs, perm, stream=stream)

    def get_page_remote(self, page: int, perm, stream: int = 0,
                        ) -> tuple["PagedKVWindow", Array]:
        """Disaggregated read path: fetch a page from a peer's pool through
        its memory handle — one request/response RTT, no target lookup.

        Carries the P5 read guarantee end to end: a stale page handle's
        response comes back **zeroed** (never the reused memory) and the drop
        is aggregated into the pool's ``err_count`` — the decode engine can
        distinguish "page freed under me" from data."""
        s = self.spec
        xfer = self.window.dup_with_info(order=True, scope="thread")
        mhwin = win_from_memhandle(xfer, self.handles[page], slot=page)
        mhwin, flat = mhwin.get(perm, offset=0, size=s.page_elems,
                                stream=stream)
        mhwin = mhwin.flush(stream)
        parent = dataclasses.replace(mhwin.parent, config=self.window.config)
        pool = self._replace(window=parent,
                             err_count=self.err_count + mhwin.err_count)
        return pool, flat.reshape(2, s.page_tokens, s.kv_heads, s.head_dim)


# ---------------------------------------------------------------------------
# Host-side pool manager: refcounts + copy-on-write sharing over physical pages
# ---------------------------------------------------------------------------


class KVPoolManager:
    """Refcounted physical-page pool with copy-on-write prefix sharing.

    The serving engine's pool layer (``docs/serving_disagg.md``): where
    :class:`repro.serve.disagg.PageAllocator` hands every sequence exclusive
    pages, this manager lets sequences with a common prompt prefix *map the
    same physical page* — a refcount per page, :meth:`share_pages` to map an
    allocated page into another sequence, and :meth:`cow_write` to fork a
    shared page the moment a holder needs to write it (vLLM-style COW on the
    paper's memhandle lifetime model: a physical page is a memhandle whose
    exposure outlives any one sequence, and the epoch machinery — not this
    bookkeeping — is what catches a stale access if the two ever disagree).

    Bookkeeping is O(sequences touching a page), never O(pool): refcounts
    are per-page integers, the free list is FIFO (freed pages are reused as
    late as possible — maximum grace for in-flight transfers), and the COW
    fork debt is derived from the handful of writable-shared pages.

    Guards: releasing a page with refcount 0 (double free / never
    allocated) raises with the page id; so does sharing or cow-writing one.
    :meth:`can_admit` reserves one free page per outstanding writable share
    (each such holder may still fork), so admission never promises pages a
    later COW fault will need.
    """

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._ref = [0] * n_pages
        self._free = list(range(n_pages))
        self._cow: set[int] = set()      # writable-shared pages (may fork)
        self.allocs = 0
        self.frees = 0
        self.cow_copies = 0
        self.shared_maps = 0

    # -- capacity ---------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def cow_debt(self) -> int:
        """Free pages that must stay reserved for pending COW forks: every
        extra holder of a writable-shared page will fork exactly once."""
        return sum(self._ref[p] - 1 for p in self._cow if self._ref[p] > 1)

    def can_admit(self, n_fresh: int, n_writable_shares: int = 0) -> bool:
        """Would allocating ``n_fresh`` pages plus taking
        ``n_writable_shares`` new writable shares stay fork-safe?"""
        return len(self._free) - self.cow_debt >= n_fresh + n_writable_shares

    # -- lifecycle ---------------------------------------------------------------
    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted: need {n} pages, "
                f"{len(self._free)}/{self.n_pages} free")
        pages, self._free = self._free[:n], self._free[n:]
        for p in pages:
            self._ref[p] = 1
        self.allocs += n
        return pages

    def refcount_of(self, page: int) -> int:
        return self._ref[page]

    def share_pages(self, pages, *, writable: bool = False) -> None:
        """Map already-allocated pages into one more sequence (refcount+1).

        ``writable=True`` marks the share copy-on-write: the page sits at a
        holder's future write position (a partial prefix page) and one free
        page is reserved per extra holder for the eventual fork."""
        for p in pages:
            if self._ref[p] <= 0:
                raise ValueError(f"share_pages({p}): page is not allocated")
            self._ref[p] += 1
            if writable:
                self._cow.add(p)
        self.shared_maps += len(pages)

    def cow_write(self, page: int) -> tuple[int, bool]:
        """Resolve a write to ``page``: ``(page, False)`` if this holder is
        the sole owner (write in place), else fork — allocate a fresh page,
        move one reference onto it, and return ``(new_page, True)``; the
        caller copies the contents and remaps its page table."""
        if self._ref[page] <= 0:
            raise ValueError(f"cow_write({page}): page is not allocated")
        if self._ref[page] == 1:
            self._cow.discard(page)
            return page, False
        if not self._free:
            raise RuntimeError(
                f"cow_write({page}): pool exhausted at fork "
                f"(admission outran the COW reserve)")
        new = self._free.pop(0)
        self._ref[new] = 1
        self._ref[page] -= 1
        if self._ref[page] <= 1:
            self._cow.discard(page)
        self.allocs += 1
        self.cow_copies += 1
        return new, True

    def release(self, pages) -> list[int]:
        """Drop one reference per page; pages reaching refcount 0 return to
        the FIFO free list.  Returns the pages whose refcount dropped to
        ``<= 1`` (no longer shared — the engine clears their write
        protection).  Raises on double free with the offending page id."""
        dropped = []
        for p in pages:
            if self._ref[p] <= 0:
                raise ValueError(
                    f"release({p}): double free (page is not allocated)")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
                self.frees += 1
                self._cow.discard(p)
                dropped.append(p)
            elif self._ref[p] == 1:
                self._cow.discard(p)
                dropped.append(p)
        return dropped

    # -- health ----------------------------------------------------------------
    def stats(self) -> dict:
        live = sum(1 for r in self._ref if r > 0)
        return {
            "n_pages": self.n_pages,
            "n_free": len(self._free),
            "live_pages": live,
            "occupancy": live / max(self.n_pages, 1),
            "allocs": self.allocs,
            "frees": self.frees,
            "cow_copies": self.cow_copies,
            "shared_maps": self.shared_maps,
            "cow_debt": self.cow_debt,
        }


__all__ = ["PageSpec", "PagedKVWindow", "KVPoolManager", "transfer_plan"]
