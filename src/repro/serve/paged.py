"""Paged KV-cache as a dynamic RMA window — the serving-side use of P5.

The serving engine's KV pool is the TPU analogue of the paper's dynamic
window: pages (fixed-size token blocks) are *attached* segments of a
process-local pool, allocated and freed as sequences come and go — exactly
the "communication requirements change over time" motivation of paper §4.

Access paths, mirroring the paper's measurement taxonomy:

* ``query``    — the page's registration (offset/epoch) is looked up
  remotely per access (dynamic window without handles; Fig. 3b),
* ``memhandle`` — page descriptors are exchanged once at allocation; decode-
  time accesses are direct RDMA with zero lookup overhead (P5).  A page's
  handle dies with ``free_page`` (epoch bump) — use-after-free is dropped
  and counted, never corrupts (the life-time guarantee).
* ``accumulate_page`` — in-place remote page updates (running KV stats,
  correction deltas, counters) through the op-specialized accumulate engine
  on a same-op dup'd view (paper §2.3 hints × P4), addressed via the page's
  memory handle.

A disaggregated prefill→decode deployment ships page handles instead of page
contents; ``benchmarks.put_latency`` quantifies the per-access win.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.rma import (
    DynamicWindow,
    WindowConfig,
    memhandle_create,
    memhandle_release,
    win_from_memhandle,
)

Array = jax.Array

from repro.core.rma.plan import register_plan_cache as _register_plan_cache

_TRANSFER_PLANS: dict[tuple, object] = _register_plan_cache(
    "kv_transfer", {})


def transfer_plan(pool_pages: int, pages: tuple, page_elems: int, dtype,
                  perm: tuple, stream: int = 0, *,
                  naive_flush: bool = False, topology=None,
                  backend: str = "rma"):
    """Build (or fetch from the build-once cache) the compiled page-push
    schedule: one :meth:`RmaPlan.put_handle` per page on the batch's ordered
    stream, one exit flush epoch — 2 phases per page (payload + handle
    header) + 2 for the epoch, never a per-page ack.

    ``topology``: the declared host factorization (see
    ``repro.core.rma.Topology``).  A push whose ``perm`` stays on one host
    (e.g. prefill and decode pools co-located) is classified into the
    shared-memory tier — same 2-phase pages, but the exit epoch drains
    nothing.  Part of the cache key: a pool re-created under a different
    factorization never replays the old schedule.

    ``backend``: lowering target for :meth:`RmaPlan.compile`.  Page pushes
    record no collective macro, so ``"auto"``/``"gspmd"`` resolve to the
    substrate schedule; ``"interpret"`` compiles and executes through
    :meth:`CompiledPlan.interpret` only when given ``regs=`` registration
    state (without it the handle path raises)."""
    from repro.core.rma.plan import RmaPlan
    from repro.core.rma.topology import topology_fingerprint

    if backend == "auto":
        backend = "rma"        # no macro to ever pick gspmd for
    dt = jnp.dtype(dtype)
    key = (pool_pages, tuple(pages), page_elems, dt.name, perm, stream,
           naive_flush, topology_fingerprint(topology), backend)
    if key in _TRANSFER_PLANS:
        return _TRANSFER_PLANS[key]
    plan = RmaPlan(f"transfer_pages[{len(pages)}]", topology=topology)
    plan.window("pool", scope="thread", order=True, max_streams=stream + 1,
                dtype=dt, exit_epoch=True)
    plan.bind("handles", (pool_pages, 4), jnp.int32)
    for i, page in enumerate(pages):
        plan.bind(f"kv{i}", (page_elems,), dt)
        plan.put_handle("pool", f"kv{i}",
                        lambda env, p=page: env["handles"][p], perm,
                        slot=page, stream=stream, shape=(page_elems,),
                        dtype=dt, label=f"page{page}")
    compiled = plan.compile(naive_flush=naive_flush, backend=backend)
    _TRANSFER_PLANS[key] = compiled
    return compiled


@dataclasses.dataclass(frozen=True)
class PageSpec:
    page_tokens: int          # tokens per page
    kv_heads: int
    head_dim: int
    n_pages: int              # pool capacity

    @property
    def page_elems(self) -> int:
        return self.page_tokens * self.kv_heads * self.head_dim * 2  # K and V


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVWindow:
    """Fixed-capacity page pool exposed as a dynamic window.

    ``window.buffer`` is the flat pool; page *p* occupies
    ``[p·page_elems, (p+1)·page_elems)``.  ``page_map`` (host side) tracks
    free pages; ``handles`` holds each live page's memory handle (what a
    remote decode engine would receive).

    ``err_count`` aggregates the P5 stale-handle drops observed across every
    handle-path transfer issued through this pool (put / get / accumulate /
    batched transfers) — the per-transfer ``MemhandleWindow`` counters would
    otherwise die with their throwaway view.  The disagg engine surfaces it
    in its serving stats; a non-zero value means a peer pushed (or read)
    through a freed page's handle.
    """

    window: DynamicWindow
    handles: Array            # (n_pages, 4) int32 — live pages' memhandles
    live: Array               # (n_pages,) bool
    spec: PageSpec
    err_count: Array = None   # () int32 — aggregated stale-handle violations

    def __post_init__(self):
        if self.err_count is None:
            self.err_count = jnp.zeros((), jnp.int32)

    def tree_flatten(self):
        return (self.window, self.handles, self.live, self.err_count), (self.spec,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux[0], children[3])

    def _replace(self, **kw) -> "PagedKVWindow":
        return dataclasses.replace(self, **kw)

    # -- construction ---------------------------------------------------------
    @classmethod
    def create(cls, spec: PageSpec, axis: str, axis_size: int,
               dtype=jnp.bfloat16, *, topology=None) -> "PagedKVWindow":
        pool = jnp.zeros((spec.n_pages * spec.page_elems,), dtype)
        win = DynamicWindow.create_dynamic(
            pool, axis, axis_size,
            WindowConfig(scope="thread", order=True, max_streams=4,
                         topology=topology),
            max_attach=spec.n_pages, am_slots=1, am_msg=1)
        return cls(
            window=win,
            handles=jnp.zeros((spec.n_pages, 4), jnp.int32),
            live=jnp.zeros((spec.n_pages,), bool),
            spec=spec,
        )

    # -- page lifecycle ---------------------------------------------------------
    def alloc_page(self, page: int) -> "PagedKVWindow":
        """Attach page ``page`` and create its memory handle (P5): local,
        no communication — the handle is what peers get.

        Allocating a page that is already live raises with the page id
        (symmetric with the :meth:`free_page` double-free guard): a second
        attach would re-register the slot and mint a handle at the *same*
        epoch as the outstanding ones, silently re-arming every stale copy.
        As with ``free_page``, the guard runs whenever liveness is concrete;
        under a trace the epoch machinery remains the backstop."""
        import jax.core

        s = self.spec
        live = self.live[page] if 0 <= page < s.n_pages else False
        if not isinstance(live, jax.core.Tracer) and bool(live):
            raise ValueError(
                f"alloc_page({page}): page is already allocated "
                f"(double alloc — free_page it before re-attaching)")
        win = self.window.attach(page, offset=page * s.page_elems,
                                 size=s.page_elems)
        mh = memhandle_create(win, page)
        return self._replace(window=win, handles=self.handles.at[page].set(mh),
                             live=self.live.at[page].set(True))

    def free_page(self, page: int) -> "PagedKVWindow":
        """Release through the substrate's consolidated lifetime machinery:
        ``memhandle_release`` invalidates the slot, bumps the traced epoch
        (stale remote writes are dropped and counted) and records the release
        in the dup family's flush queues, so statically-created handle
        windows for this page raise on use-after-free.

        Freeing a page that is not live (double free, or never allocated)
        raises with the page id: a second release would bump the epoch past
        the one outstanding handles were checked against and silently re-arm
        a dead slot.  The guard runs whenever liveness is concrete (eager
        host-side pool management); under a trace the liveness bit is a
        tracer and the epoch machinery remains the backstop."""
        import jax.core

        live = self.live[page] if 0 <= page < self.spec.n_pages else False
        if not isinstance(live, jax.core.Tracer) and not bool(live):
            raise ValueError(
                f"free_page({page}): page is not allocated "
                f"(double free, or never alloc_page'd)")
        win = memhandle_release(self.window, page)
        return self._replace(window=win, handles=self.handles.at[page].set(0),
                             live=self.live.at[page].set(False))

    # -- data paths ---------------------------------------------------------------
    def write_page_local(self, page: int, kv: Array) -> "PagedKVWindow":
        """Local fill (the prefill engine writing its own pool)."""
        s = self.spec
        buf = jax.lax.dynamic_update_slice_in_dim(
            self.window.buffer, kv.reshape(-1).astype(self.window.buffer.dtype),
            page * s.page_elems, axis=0)
        return self._replace(window=self.window._with(buffer=buf))

    def read_page(self, page: int) -> Array:
        s = self.spec
        flat = jax.lax.dynamic_slice_in_dim(
            self.window.buffer, page * s.page_elems, s.page_elems, axis=0)
        return flat.reshape(2, s.page_tokens, s.kv_heads, s.head_dim)

    def put_page_remote(self, page: int, kv: Array, perm,
                        stream: int = 0, *, order: bool = True,
                        ) -> "PagedKVWindow":
        """Disaggregated path: push a filled page into a peer's pool through
        its memory handle — one RDMA phase, no target involvement.

        The transfer runs on a **duplicated view** of the pool window (paper
        P4) carrying the per-transfer config (ordered channel, thread-scope
        completion) — same backing pool, same flush queues, zero copies —
        instead of re-allocating or disturbing the pool's own config."""
        xfer = self.window.dup_with_info(order=order, scope="thread")
        mhwin = win_from_memhandle(xfer, self.handles[page], slot=page)
        mhwin = mhwin.put(kv.reshape(-1), perm, stream=stream)
        mhwin = mhwin.flush(stream)
        parent = dataclasses.replace(mhwin.parent, config=self.window.config)
        return self._replace(window=parent,
                             err_count=self.err_count + mhwin.err_count)

    def accumulate_page(self, page: int, update: Array, perm, *,
                        op: str = "sum", offset: int = 0, stream: int = 0,
                        ) -> "PagedKVWindow":
        """In-place remote update of a live page — running KV statistics,
        speculative-decode correction deltas, visit counters — through the
        op-specialized accumulate engine.

        The update travels through a **dup'd view declaring single-op usage**
        (``same_op=op``, paper §2.3 hints × P4 dup): small updates on atomic-
        capable dtypes lower to the 1-phase NIC-atomic path, large ones to
        the tiled VPU bandwidth path — never the conservative generic path a
        hint-less accumulate would take.  Addressing goes through the page's
        memory handle (P5), so the target is not involved in the lookup."""
        view = self.window.dup_with_info(order=True, scope="thread",
                                         same_op=op, accumulate_ops=(op,))
        mhwin = win_from_memhandle(view, self.handles[page], slot=page)
        mhwin = mhwin.accumulate(update.reshape(-1), perm, op=op,
                                 offset=offset, stream=stream)
        mhwin = mhwin.flush(stream)
        parent = dataclasses.replace(mhwin.parent, config=self.window.config)
        return self._replace(window=parent,
                             err_count=self.err_count + mhwin.err_count)

    def push_pages(self, pages, kvs, perm, stream: int = 0, *,
                   backend: str = "rma") -> "PagedKVWindow":
        """Batched disaggregated push as a **declarative-plan replay**: the
        batch's schedule — every page issued back-to-back through its memory
        handle on one ordered stream, one thread-scoped flush epoch for the
        whole batch, no per-page acks — is planned once per (pages, shape)
        signature and cached; each call replays it with this step's handles
        and payloads.  ``pages`` must be static (Python ints): the per-page
        registration slots are part of the plan, which is what arms the P5
        trace-time use-after-release check on every replay."""
        compiled = transfer_plan(
            self.spec.n_pages, tuple(pages), self.spec.page_elems,
            self.window.buffer.dtype, tuple(tuple(p) for p in perm), stream,
            topology=self.window.config.topology, backend=backend)
        bindings = {"handles": self.handles}
        for i, kv in enumerate(kvs):
            bindings[f"kv{i}"] = kv.reshape(-1).astype(self.window.buffer.dtype)
        res = compiled.execute({"pool": self.window}, bindings)
        return self._replace(window=res.windows["pool"],
                             err_count=self.err_count + res.err_count)

    def transfer_pages(self, pages, kvs, perm, stream: int = 0,
                       ) -> "PagedKVWindow":
        """Batched disaggregated push: every page is issued back-to-back on
        one dup'd ordered view and a **single** thread-scoped flush epoch
        completes the whole batch — the pipelined put+signal shape of the
        cross-pod exchange, applied to KV pages.  ``pages`` must be static
        (Python ints): the per-page handles are resolved at trace time.

        .. deprecated:: kept as a thin wrapper over the plan-native
           :meth:`push_pages` (same numerics, same phase structure); emits a
           ``DeprecationWarning`` once per process."""
        from repro.core.rma.plan import warn_legacy_once

        warn_legacy_once("PagedKVWindow.transfer_pages",
                         "PagedKVWindow.push_pages (plan replay)")
        return self.push_pages(pages, kvs, perm, stream=stream)

    def get_page_remote(self, page: int, perm, stream: int = 0,
                        ) -> tuple["PagedKVWindow", Array]:
        """Disaggregated read path: fetch a page from a peer's pool through
        its memory handle — one request/response RTT, no target lookup.

        Carries the P5 read guarantee end to end: a stale page handle's
        response comes back **zeroed** (never the reused memory) and the drop
        is aggregated into the pool's ``err_count`` — the decode engine can
        distinguish "page freed under me" from data."""
        s = self.spec
        xfer = self.window.dup_with_info(order=True, scope="thread")
        mhwin = win_from_memhandle(xfer, self.handles[page], slot=page)
        mhwin, flat = mhwin.get(perm, offset=0, size=s.page_elems,
                                stream=stream)
        mhwin = mhwin.flush(stream)
        parent = dataclasses.replace(mhwin.parent, config=self.window.config)
        pool = self._replace(window=parent,
                             err_count=self.err_count + mhwin.err_count)
        return pool, flat.reshape(2, s.page_tokens, s.kv_heads, s.head_dim)


# ---------------------------------------------------------------------------
# Host-side pool management: tier-generic refcounted core + the tiered manager
# ---------------------------------------------------------------------------

#: Residency states a physical page moves through in the tiered pool.
RESIDENT_HOT = "hot"            # device-resident, decodable
RESIDENT_COLD = "cold"          # host-resident (demoted), not decodable
RESIDENT_IN_FLIGHT = "in-flight"  # queued/under migration between tiers


class PageTier:
    """One memory tier's refcounted page core with copy-on-write sharing.

    This is the tier-generic half of the pool split: everything that makes
    "a page" safe to own — refcounts, the FIFO free list (freed pages are
    reused as late as possible, maximum grace for in-flight transfers),
    the COW ledger and fork-debt reserve, and the double-free / not-
    allocated guards — parameterized only by a name and a capacity.
    :class:`KVPoolManager` composes two of these (the HBM hot tier and the
    host-memory cold tier) and layers residency/migration state on top;
    neither tier knows the other exists.

    Guards: releasing a page with refcount 0 (double free / never
    allocated) raises with the page id; so does sharing or cow-writing one.
    :meth:`can_admit` reserves one free page per outstanding writable share
    (each such holder may still fork), so admission never promises pages a
    later COW fault will need.
    """

    def __init__(self, name: str, capacity: int):
        self.name = name
        self.capacity = capacity
        self._ref = [0] * capacity
        self._free = list(range(capacity))
        # writable-shared pages -> writer count (owner + writable sharers);
        # read-only sharers hold references but never fork
        self._cow: dict[int, int] = {}
        self.allocs = 0
        self.frees = 0
        self.cow_copies = 0
        self.shared_maps = 0

    # -- capacity ---------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def cow_debt(self) -> int:
        """Free pages that must stay reserved for pending COW forks.

        Per writable-shared page the worst case is ``min(writers, ref - 1)``
        forks: every writer forks while other references pin the page, and
        the last writer writes in place only when no read-only holder
        remains (all-writable sharing keeps the classic ``ref - 1``)."""
        return sum(min(w, self._ref[p] - 1)
                   for p, w in self._cow.items() if self._ref[p] > 1)

    def can_admit(self, n_fresh: int, n_writable_shares: int = 0) -> bool:
        """Would allocating ``n_fresh`` pages plus ``n_writable_shares``
        more units of fork debt stay fork-safe?  Price shares with
        :meth:`share_price` — a writable share of a page that already has
        read-only holders costs *more* than one unit (the owner is dragged
        into forking too)."""
        return len(self._free) - self.cow_debt >= n_fresh + n_writable_shares

    def share_price(self, pages, *, writable: bool = False) -> int:
        """The COW-debt delta :meth:`share_pages` of ``pages`` would incur —
        what admission must pass to :meth:`can_admit`.  Non-writable shares
        are not free either: one more read-only holder of a writable-shared
        page can push its last writer from write-in-place to fork."""
        ref = {p: self._ref[p] for p in set(pages)}
        wrt = {p: self._cow.get(p) for p in set(pages)}

        def debt(p):
            w = wrt[p]
            return min(w, ref[p] - 1) if w is not None and ref[p] > 1 else 0

        delta = 0
        for p in pages:
            before = debt(p)
            ref[p] += 1
            if writable:
                wrt[p] = (wrt[p] if wrt[p] is not None else 1) + 1
            delta += debt(p) - before
        return delta

    # -- lifecycle ---------------------------------------------------------------
    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted ({self.name} tier): need {n} "
                f"pages, {len(self._free)}/{self.capacity} free")
        pages, self._free = self._free[:n], self._free[n:]
        for p in pages:
            self._ref[p] = 1
        self.allocs += n
        return pages

    def refcount_of(self, page: int) -> int:
        return self._ref[page]

    def share_pages(self, pages, *, writable: bool = False) -> None:
        """Map already-allocated pages into one more sequence (refcount+1).

        ``writable=True`` marks the share copy-on-write: the page sits at a
        holder's future write position (a partial prefix page) and one free
        page is reserved per extra holder for the eventual fork."""
        for p in pages:
            if self._ref[p] <= 0:
                raise ValueError(f"share_pages({p}): page is not allocated")
            self._ref[p] += 1
            if writable:
                self._cow[p] = self._cow.get(p, 1) + 1
        self.shared_maps += len(pages)

    def cow_write(self, page: int) -> tuple[int, bool]:
        """Resolve a write to ``page``: ``(page, False)`` if this holder is
        the sole owner (write in place), else fork — allocate a fresh page,
        move one reference onto it, and return ``(new_page, True)``; the
        caller copies the contents and remaps its page table."""
        if self._ref[page] <= 0:
            raise ValueError(f"cow_write({page}): page is not allocated")
        if self._ref[page] == 1:
            self._cow.pop(page, None)
            return page, False
        if not self._free:
            raise RuntimeError(
                f"cow_write({page}): pool exhausted at fork "
                f"(admission outran the COW reserve)")
        new = self._free.pop(0)
        self._ref[new] = 1
        self._ref[page] -= 1
        if page in self._cow:
            self._cow[page] -= 1     # the forking writer moved off the page
            if self._cow[page] <= 0 or self._ref[page] <= 1:
                del self._cow[page]
        self.allocs += 1
        self.cow_copies += 1
        return new, True

    def release(self, pages) -> list[int]:
        """Drop one reference per page; pages reaching refcount 0 return to
        the FIFO free list.  Returns the pages whose refcount dropped to
        ``<= 1`` (no longer shared — the engine clears their write
        protection).  Raises on double free with the offending page id."""
        dropped = []
        for p in pages:
            if self._ref[p] <= 0:
                raise ValueError(
                    f"release({p}): double free (page is not allocated)")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
                self.frees += 1
                self._cow.pop(p, None)
                dropped.append(p)
            elif self._ref[p] == 1:
                self._cow.pop(p, None)
                dropped.append(p)
        return dropped

    def check_conservation(self) -> None:
        """Assert the tier's conservation invariants (the Hypothesis sweep's
        oracle): every page is exactly one of free or refcounted — live
        count + free-list size == capacity, the free list holds no
        duplicates and no live page, refcounts are non-negative, and the COW
        fork debt never exceeds the free pages backing it."""
        live = sum(1 for r in self._ref if r > 0)
        assert live + len(self._free) == self.capacity, \
            f"{self.name}: {live} live + {len(self._free)} free " \
            f"!= {self.capacity} pages"
        assert len(set(self._free)) == len(self._free), \
            f"{self.name}: duplicate pages in the free list"
        assert all(self._ref[p] == 0 for p in self._free), \
            f"{self.name}: live page on the free list"
        assert all(r >= 0 for r in self._ref), \
            f"{self.name}: negative refcount"
        assert self.cow_debt <= len(self._free), \
            f"{self.name}: cow_debt {self.cow_debt} exceeds " \
            f"{len(self._free)} free pages"


class KVPoolManager:
    """Tiered physical-page pool: an HBM hot tier + a host-memory cold tier.

    The serving engine's pool layer (``docs/serving_disagg.md``): where
    :class:`repro.serve.disagg.PageAllocator` hands every sequence exclusive
    pages, this manager lets sequences with a common prompt prefix *map the
    same physical page* — a refcount per page, :meth:`share_pages` to map an
    allocated page into another sequence, and :meth:`cow_write` to fork a
    shared page the moment a holder needs to write it (vLLM-style COW on the
    paper's memhandle lifetime model: a physical page is a memhandle whose
    exposure outlives any one sequence, and the epoch machinery — not this
    bookkeeping — is what catches a stale access if the two ever disagree).

    With ``host_pages > 0`` the pool becomes a **memory hierarchy**
    ("MPI Windows on Storage" applied to KV): two :class:`PageTier` cores —
    ``hbm`` (what decode reads) and ``host`` (cold spill, backed by a
    host-memory :class:`PagedKVWindow` at the engine layer) — plus
    per-page residency state and demotion/promotion queues.  Page naming is
    tier-scoped: ``("hbm", p)`` and ``("host", s)`` are different physical
    pages; a migration copies payload between them and retires one side.
    The refcount/COW machinery lives entirely in the hot tier — sharing
    dissolves at demotion (the cold copy is private to its sequence) so a
    cold page has exactly one owner by construction.

    Every pre-tier entry point (``alloc``/``release``/``share_pages``/
    ``cow_write``/``can_admit``/counters/``stats()``) delegates to the hot
    tier unchanged — a ``KVPoolManager(n)`` without host pages is
    behaviorally identical to the pre-hierarchy flat pool, FIFO order and
    error messages included.
    """

    def __init__(self, n_pages: int, host_pages: int = 0):
        self.hbm = PageTier("hbm", n_pages)
        self.host = PageTier("host", host_pages)
        #: residency by (tier_name, page): RESIDENT_* or absent (free)
        self._residency: dict[tuple[str, int], str] = {}
        self._demote_q: list[tuple[int, int]] = []   # (hbm_page, host_slot)
        self._promote_q: list[int] = []              # host_slot
        self.demotions = 0
        self.promotions = 0

    # -- hot-tier delegation (the pre-tier surface, byte-identical) ----------
    @property
    def n_pages(self) -> int:
        return self.hbm.capacity

    @property
    def n_free(self) -> int:
        return self.hbm.n_free

    @property
    def cow_debt(self) -> int:
        return self.hbm.cow_debt

    @property
    def allocs(self) -> int:
        return self.hbm.allocs

    @property
    def frees(self) -> int:
        return self.hbm.frees

    @property
    def cow_copies(self) -> int:
        return self.hbm.cow_copies

    @property
    def shared_maps(self) -> int:
        return self.hbm.shared_maps

    @property
    def _ref(self):
        return self.hbm._ref

    @property
    def _free(self):
        return self.hbm._free

    @property
    def _cow(self):
        return self.hbm._cow

    def can_admit(self, n_fresh: int, n_writable_shares: int = 0) -> bool:
        """Decode-set admission: would the **hot tier alone** back
        ``n_fresh`` fresh pages plus ``n_writable_shares`` writable shares,
        fork-safe?  (Total-footprint pricing against HBM+host is the
        scheduler's :meth:`~repro.serve.scheduler.Scheduler.
        price_admission`; this is the per-tick decode-set half.)"""
        return self.hbm.can_admit(n_fresh, n_writable_shares)

    def share_price(self, pages, *, writable: bool = False) -> int:
        return self.hbm.share_price(pages, writable=writable)

    def alloc(self, n: int) -> list[int]:
        pages = self.hbm.alloc(n)
        for p in pages:
            self._residency[("hbm", p)] = RESIDENT_HOT
        return pages

    def refcount_of(self, page: int) -> int:
        return self.hbm.refcount_of(page)

    def share_pages(self, pages, *, writable: bool = False) -> None:
        self.hbm.share_pages(pages, writable=writable)

    def cow_write(self, page: int) -> tuple[int, bool]:
        new, forked = self.hbm.cow_write(page)
        if forked:
            self._residency[("hbm", new)] = RESIDENT_HOT
        return new, forked

    def release(self, pages) -> list[int]:
        dropped = self.hbm.release(pages)
        for p in dropped:
            if self.hbm.refcount_of(p) == 0:
                self._residency.pop(("hbm", p), None)
        return dropped

    # -- cold tier + residency -----------------------------------------------
    def alloc_cold(self, n: int) -> list[int]:
        """Take ``n`` host-tier slots for incoming demotions; they report
        in-flight until :meth:`drain_demotes` lands the payloads."""
        slots = self.host.alloc(n)
        for s in slots:
            self._residency[("host", s)] = RESIDENT_IN_FLIGHT
        return slots

    def free_cold(self, slots) -> None:
        """Retire cold copies (their sequence promoted back, or finished).
        The backing window's ``free_page`` epoch bump — not this
        bookkeeping — is what makes outstanding handles stale."""
        self.host.release(slots)
        gone = set(slots)
        self._promote_q = [s for s in self._promote_q if s not in gone]
        for s in slots:
            self._residency.pop(("host", s), None)

    def residency(self, tier: str, page: int) -> str | None:
        """RESIDENT_* for a live page of ``tier`` (``"hbm"``/``"host"``),
        ``None`` if the page is free/unknown."""
        return self._residency.get((tier, page))

    def queue_demote(self, hbm_page: int, host_slot: int) -> None:
        """Stage one page for demotion: both sides report in-flight until
        the planned put lands and :meth:`drain_demotes` commits."""
        self._residency[("hbm", hbm_page)] = RESIDENT_IN_FLIGHT
        self._residency[("host", host_slot)] = RESIDENT_IN_FLIGHT
        self._demote_q.append((hbm_page, host_slot))

    def drain_demotes(self) -> list[tuple[int, int]]:
        """Commit every staged demotion (the planned puts completed): cold
        copies become resident, the HBM side returns to ``hot`` for the
        caller to release.  Returns the drained (hbm_page, host_slot)
        pairs."""
        pairs, self._demote_q = self._demote_q, []
        for hp, hs in pairs:
            self._residency[("hbm", hp)] = RESIDENT_HOT
            self._residency[("host", hs)] = RESIDENT_COLD
        self.demotions += len(pairs)
        return pairs

    def queue_promote(self, host_slots) -> None:
        """Schedule cold copies for promotion next tick (they report
        in-flight — neither decodable nor reclaimable while queued)."""
        for s in host_slots:
            self._residency[("host", s)] = RESIDENT_IN_FLIGHT
            self._promote_q.append(s)

    def drain_promotes(self, host_slots=None) -> list[int]:
        """Commit promotions for ``host_slots`` (default: everything
        queued): drop them from the queue and count them.  The caller
        lands the payloads in fresh hot pages and then :meth:`free_cold`\\ s
        the slots; a slot left queued (promotion deferred) stays
        in-flight."""
        if host_slots is None:
            done, self._promote_q = self._promote_q, []
        else:
            done = [s for s in self._promote_q if s in set(host_slots)]
            self._promote_q = [s for s in self._promote_q
                               if s not in set(host_slots)]
        self.promotions += len(done)
        return done

    def assert_resident(self, pages) -> None:
        """Raise unless every hot-tier page is decode-ready (``hot``): the
        engine's pre-decode residency check — a cold or in-flight page in a
        decode set means host and device state disagree."""
        for p in pages:
            r = self._residency.get(("hbm", p))
            if r != RESIDENT_HOT:
                raise RuntimeError(
                    f"page {p} is not resident (residency={r!r}) — "
                    "decode would read a non-hot page")

    def check_conservation(self) -> None:
        """Both tiers' conservation invariants plus the residency map's:
        every residency entry names a live page of its tier."""
        self.hbm.check_conservation()
        self.host.check_conservation()
        for (tier, p), state in self._residency.items():
            t = self.hbm if tier == "hbm" else self.host
            assert t.refcount_of(p) > 0, \
                f"residency entry for free page ({tier}, {p}): {state}"

    # -- health ----------------------------------------------------------------
    def stats(self) -> dict:
        live = sum(1 for r in self.hbm._ref if r > 0)
        st = {
            "n_pages": self.n_pages,
            "n_free": self.n_free,
            "live_pages": live,
            "occupancy": live / max(self.n_pages, 1),
            "allocs": self.allocs,
            "frees": self.frees,
            "cow_copies": self.cow_copies,
            "shared_maps": self.shared_maps,
            "cow_debt": self.cow_debt,
        }
        if self.host.capacity:
            st.update({
                "host_pages": self.host.capacity,
                "host_free": self.host.n_free,
                "cold_pages": sum(1 for v in self._residency.values()
                                  if v == RESIDENT_COLD),
                "in_flight": sum(1 for v in self._residency.values()
                                 if v == RESIDENT_IN_FLIGHT),
                "demotions": self.demotions,
                "promotions": self.promotions,
            })
        return st


# ---------------------------------------------------------------------------
# The cold tier's window: host-memory pages behind the same P5 machinery
# ---------------------------------------------------------------------------

_TIER_PLANS: dict[tuple, object] = _register_plan_cache("kv_tier_step", {})


def tier_step_plan(pool_pages: int, promote: tuple, demote: tuple,
                   page_elems: int, dtype, perm: tuple = ((0, 0),), *,
                   backend: str = "rma"):
    """Build (or fetch from the build-once cache) one decode tick's tier
    traffic as a compiled plan: promote ``get_handle``\\ s first — **prefetch
    edges** on the window's dedicated last stream — then the demote
    ``put_handle``\\ s (the cold-bound pages written behind the previous
    tick's attention) on the migration stream, then the gather that consumes
    the promoted payloads.  The planner places each promote's completion
    epoch as a ``prefetch-wait`` immediately before the gather, so the
    phase table *shows* the overlap::

        prefetch:promote[s]...   (dedicated stream, issued first)
        demote[t]...             (migration stream — overlaps the reads)
        prefetch-wait[host/3]    (promotion completes only here,
                                  provably before the gather)

    Stale handles — a cold page freed after demotion — zero-mask + count at
    the target (P5), which is what the demote→free→stale-read tests drive
    through this exact plan.  Output ``"promoted"`` stacks the fetched
    payloads ``(len(promote), page_elems)``; omitted when nothing
    promotes."""
    from repro.core.rma.plan import RmaPlan

    if backend == "auto":
        backend = "rma"        # no macro to ever pick gspmd for
    dt = jnp.dtype(dtype)
    key = (pool_pages, tuple(promote), tuple(demote), page_elems, dt.name,
           tuple(tuple(p) for p in perm), backend)
    if key in _TIER_PLANS:
        return _TIER_PLANS[key]
    plan = RmaPlan(f"kv-tier-step[p{len(promote)} d{len(demote)}]")
    plan.window("host", scope="thread", order=True, max_streams=4,
                dtype=dt, exit_epoch=True)
    plan.bind("handles", (pool_pages, 4), jnp.int32)
    gets = []
    for s in promote:
        gets.append(plan.get_handle(
            "host", lambda env, p=s: env["handles"][p], tuple(perm), slot=s,
            size=page_elems, stream=3, label=f"promote[{s}]"))
    for i, s in enumerate(demote):
        plan.bind(f"cold{i}", (page_elems,), dt)
        plan.put_handle("host", f"cold{i}",
                        lambda env, p=s: env["handles"][p], tuple(perm),
                        slot=s, stream=2, shape=(page_elems,), dtype=dt,
                        label=f"demote[{s}]")
    if gets:
        gather = plan.compute(
            lambda env: jnp.stack([env[g] for g in gets]),
            reads=tuple(gets), label="attention-gather")
        for g in gets:
            plan.prefetch(g, gather)
        plan.output("promoted", gather)
    compiled = plan.compile(backend=backend)
    _TIER_PLANS[key] = compiled
    return compiled


class HostKVTier:
    """The cold tier's storage: a host-memory page pool behind the *same*
    dynamic-window + memhandle machinery as the device pools.

    Demoted pages live as attached slots of a :class:`PagedKVWindow`
    (the "MPI Windows on Storage" move: the window abstraction extended
    down the memory hierarchy), so the P5 lifetime story applies unchanged
    — :meth:`free` releases through ``memhandle_release``, bumping the slot
    epoch, and any later promote of that slot comes back **zeroed and
    counted**, never as reused bytes.

    The serving engine is one process, so tier traffic executes the
    compiled :func:`tier_step_plan` under ``vmap(axis_name=...)`` with a
    single rank and a self-permutation — the degenerate mesh.  Same
    substrate, same epoch bookkeeping, same stale-handle guarantees as a
    real multi-device deployment (``tests/mdev/kv_tier.py`` runs the same
    plans on an 8-device mesh).

    A "page" here is one sequence page's **full payload across every pool
    the model keeps** (all layers' K and V bytes concatenated —
    ``page_elems`` from ``Executor.page_payload_elems``), so one slot
    round-trips one logical KV page regardless of how many scan-stacked
    pools back it on device."""

    def __init__(self, n_pages: int, page_elems: int, dtype, *,
                 axis: str = "x"):
        if page_elems % 2:
            raise ValueError(f"page_elems must be even, got {page_elems}")
        self.axis = axis
        # PageSpec models elems as tokens*heads*dim*2; the host tier stores
        # opaque payload bytes, so fold everything into the token factor
        self.spec = PageSpec(page_tokens=page_elems // 2, kv_heads=1,
                             head_dim=1, n_pages=n_pages)
        self.pool = PagedKVWindow.create(self.spec, axis, 1, dtype)
        self.dtype = jnp.dtype(dtype)

    @property
    def err_count(self) -> Array:
        """Aggregated P5 stale-handle drops observed by tier traffic."""
        return self.pool.err_count

    def alloc(self, slots) -> None:
        """Attach host slots (fresh handles) for incoming demotions."""
        for s in slots:
            self.pool = self.pool.alloc_page(int(s))

    def free(self, slots) -> None:
        """Release host slots through ``memhandle_release``: the epoch bump
        is the guarantee that a demoted-then-freed page is never read."""
        for s in slots:
            self.pool = self.pool.free_page(int(s))

    def step(self, promote_slots, demote_slots, demote_payloads):
        """Run one planned tier step: promote reads (prefetch edges) +
        demote writes, one replay.  ``demote_payloads`` is
        ``(len(demote_slots), page_elems)``; returns the promoted payloads
        ``(len(promote_slots), page_elems)`` or ``None``."""
        promote_slots = tuple(int(s) for s in promote_slots)
        demote_slots = tuple(int(s) for s in demote_slots)
        if not promote_slots and not demote_slots:
            return None
        compiled = tier_step_plan(self.spec.n_pages, promote_slots,
                                  demote_slots, self.spec.page_elems,
                                  self.dtype)
        bindings = {"handles": self.pool.handles}
        for i in range(len(demote_slots)):
            bindings[f"cold{i}"] = jnp.asarray(
                demote_payloads[i]).reshape(-1).astype(self.dtype)
        stacked_win = jax.tree_util.tree_map(lambda x: x[None],
                                             self.pool.window)
        stacked_b = {k: v[None] for k, v in bindings.items()}

        if promote_slots:
            def run(win, binds):
                res = compiled.execute({"host": win}, binds)
                return res.windows["host"], res.outputs["promoted"], \
                    res.err_count
            win, out, errs = jax.vmap(run, axis_name=self.axis)(
                stacked_win, stacked_b)
            promoted = out[0]
        else:
            def run(win, binds):
                res = compiled.execute({"host": win}, binds)
                return res.windows["host"], res.err_count
            win, errs = jax.vmap(run, axis_name=self.axis)(
                stacked_win, stacked_b)
            promoted = None
        self.pool = self.pool._replace(
            window=jax.tree_util.tree_map(lambda x: x[0], win),
            err_count=self.pool.err_count + errs.reshape(()).astype(jnp.int32))
        return promoted


__all__ = [
    "PageSpec", "PagedKVWindow", "PageTier", "KVPoolManager", "HostKVTier",
    "transfer_plan", "tier_step_plan",
    "RESIDENT_HOT", "RESIDENT_COLD", "RESIDENT_IN_FLIGHT",
]
