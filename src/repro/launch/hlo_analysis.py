"""Loop-aware analysis of compiled (partitioned) HLO: FLOPs, HBM traffic,
collective bytes — the inputs of the three-term roofline.

Why not just ``compiled.cost_analysis()``?  Two verified facts about XLA:CPU
cost analysis (see tests/test_hlo_analysis.py):

* numbers are per-device (good — that's what the roofline wants), but
* ``while`` bodies are counted ONCE, ignoring trip counts.  With
  scan-over-layers (a 126-layer model = a 126-trip while), that under-counts
  by >100×.

So we parse the optimized HLO text ourselves:

* **FLOPs**: every ``dot`` op contributes 2·prod(result)·prod(contracting),
  recursively through fusions/calls/conditionals, ×trip-count through whiles.
  (Elementwise FLOPs are ignored — they are bandwidth, not compute, bound.)
* **HBM bytes**: fusions are XLA's unit of memory locality — a fusion reads
  its operands and writes its result once.  So traffic = Σ over *top-level*
  ops (fusion, dot, copy, collectives, dynamic-slice, ...) of operand+result
  bytes, loop-aware.  Ops inside fusion computations are VMEM-internal and
  not counted.
* **collective bytes**: operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, loop-aware.

Trip counts are recovered from the loop condition's comparison constant.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[list[int]]:
    """All array shapes in a type string (tuples give several)."""
    out = []
    for _, dims in _SHAPE_RE.findall(type_str):
        out.append([int(d) for d in dims.split(",")] if dims else [])
    return out


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    kind: str
    operands: list[str]
    attrs: str
    args: str = ""  # raw text inside the op's parentheses


@dataclasses.dataclass
class Computation:
    name: str
    params: dict  # name -> type string
    ops: dict     # name -> Op
    root: str = ""  # name of the ROOT op


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([a-z][a-z0-9\-]*)\((.*)$"
)
_PARAM_RE = re.compile(r"%?([\w\.\-]+)\s*:\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))")


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    name_re = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)")
    comment_re = re.compile(r"/\*[^*]*\*/")
    for line in text.splitlines():
        stripped = comment_re.sub("", line).strip()  # kill /*index=N*/ etc.
        if current is None:
            if stripped.endswith("{"):
                m = name_re.match(stripped)
                if not m:
                    continue
                params = {pn: pt for pn, pt in _PARAM_RE.findall(stripped)}
                current = Computation(m.group(1), params, {})
        else:
            if stripped == "}" or stripped.startswith("} "):
                comps[current.name] = current
                current = None
                continue
            m = _OP_RE.match(stripped)
            if m:
                name, rtype, kind, rest = m.groups()
                if stripped.startswith("ROOT "):
                    current.root = name
                # split operands (up to closing paren at depth 0)
                depth, end = 1, len(rest)
                for i, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i
                            break
                opnames = re.findall(r"%([\w\.\-]+)", rest[:end])
                current.ops[name] = Op(name, rtype.strip(), kind, opnames,
                                       rest[end:], rest[:end])
    return comps


def _entry_name(text: str, comps) -> str:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
    if m and m.group(1) in comps:
        return m.group(1)
    return max(comps, key=lambda c: len(comps[c].ops)) if comps else ""


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    coll_count: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    dots: int = 0
    convs: int = 0
    whiles: list = dataclasses.field(default_factory=list)

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    def add(self, other: "HloStats", scale: float = 1.0):
        self.flops += scale * other.flops
        self.hbm_bytes += scale * other.hbm_bytes
        for k in COLLECTIVE_KINDS:
            self.coll_bytes[k] += scale * other.coll_bytes[k]
            self.coll_count[k] += scale * other.coll_count[k]
        self.dots += int(scale * other.dots)
        self.convs += int(scale * other.convs)


def _operand_type(comp: Computation, comps, name: str) -> str:
    if name in comp.ops:
        return comp.ops[name].result_type
    if name in comp.params:
        return comp.params[name]
    return ""


_CONST_IN_LINE = re.compile(r"constant\((\d+)\)")


def _fusion_traffic(comp: Computation, comps, op: Op,
                    callee: Computation | None) -> int:
    """HBM traffic of one fusion op, aliasing- and slice-aware.

    Scan-of-layers bodies produce fusions whose operands are the giant
    stacked (L, ...) buffers but whose *actual* reads are one
    ``dynamic-slice`` per iteration, and whose root is (a tuple of)
    ``dynamic-update-slice`` writing one layer's slice in place.  Counting
    full operand/result sizes there overstates traffic ~L× — so:

    * a fusion parameter whose only uses are ``dynamic-slice`` contributes
      the slice sizes, not the buffer size;
    * a parameter consumed as the aliased (operand 0) buffer of a root
      ``dynamic-update-slice`` contributes nothing (in-place);
    * each dus root element contributes 2·update bytes instead of the
      full result element.
    """
    reads = sum(_shape_bytes(_operand_type(comp, comps, on))
                for on in op.operands)
    writes = _shape_bytes(op.result_type)
    if callee is None:
        return reads + writes
    # root (possibly a tuple of) dynamic-update-slice → in-place writes
    root = callee.ops.get(callee.root)
    dus_roots: list[Op] = []
    if root is not None:
        elems = ([callee.ops[on] for on in root.operands if on in callee.ops]
                 if root.kind == "tuple" else [root])
        dus_roots = [r for r in elems if r.kind == "dynamic-update-slice"]
    for r in dus_roots:
        full = _shape_bytes(r.result_type)
        upd = (_shape_bytes(_operand_type(callee, comps, r.operands[1]))
               if len(r.operands) > 1 else 0)
        writes += 2 * upd - full  # in-place: only the slice moves (r+w)
    # parameter-wise read refinement
    params = list(callee.params)
    uses: dict[str, list[Op]] = {pn: [] for pn in params}
    for o2 in callee.ops.values():
        for j, on in enumerate(o2.operands):
            if on in uses:
                uses[on].append(o2)
    dus_alias_params = {r.operands[0] for r in dus_roots if r.operands}
    for j, pn in enumerate(params):
        if j >= len(op.operands):
            break
        outer = _shape_bytes(_operand_type(comp, comps, op.operands[j]))
        pu = uses.get(pn, [])
        effective = None
        if pn in dus_alias_params:
            # aliased in-place buffer: reads only via explicit slices
            effective = sum(2 * _shape_bytes(u.result_type) for u in pu
                            if u.kind == "dynamic-slice")
        elif pu and all(u.kind == "dynamic-slice" for u in pu):
            effective = sum(_shape_bytes(u.result_type) for u in pu)
        if effective is not None and effective < outer:
            reads += effective - outer
    return max(reads, 0) + max(writes, 0)


def analyze(text: str) -> HloStats:
    comps = parse_module(text)

    # constants per computation (for trip counts): name -> int value
    const_vals: dict[str, dict[str, int]] = {}
    for cname, comp in comps.items():
        vals = {}
        for op in comp.ops.values():
            if op.kind == "constant":
                m = re.match(r"\s*(\d+)\s*$", op.args)
                if m:
                    vals[op.name] = int(m.group(1))
        const_vals[cname] = vals

    def trip_count(cond_name: str) -> int:
        comp = comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for op in comp.ops.values():
            if op.kind == "compare":
                for on in op.operands:
                    if on in const_vals[cond_name]:
                        best = max(best, const_vals[cond_name][on])
                    # constant inlined in operand list: compare(%x, s32[] constant(5))?
        if best == 1:  # fallback: any constant in the condition
            vals = const_vals[cond_name].values()
            best = max(vals) if vals else 1
        return best

    FUSION_LIKE = {"fusion"}
    CALL_LIKE = {"call", "custom-call", "map", "reduce", "reduce-window",
                 "scatter", "sort", "select-and-scatter"}

    memo_full: dict[str, HloStats] = {}   # flops+colls, recursing into fusions
    memo_flops_only: dict[str, HloStats] = {}

    def analyze_comp(cname: str, *, inside_fusion: bool) -> HloStats:
        memo = memo_flops_only if inside_fusion else memo_full
        if cname in memo:
            return memo[cname]
        stats = HloStats()
        memo[cname] = stats
        comp = comps.get(cname)
        if comp is None:
            return stats
        for op in comp.ops.values():
            kind = op.kind
            base = kind.replace("-start", "").replace("-done", "")
            if base in COLLECTIVE_KINDS and not kind.endswith("-done"):
                obytes = sum(
                    _shape_bytes(_operand_type(comp, comps, on))
                    for on in op.operands) or _shape_bytes(op.result_type)
                stats.coll_bytes[base] += obytes
                stats.coll_count[base] += 1
                if not inside_fusion:
                    stats.hbm_bytes += obytes + _shape_bytes(op.result_type)
                continue
            if kind == "dot":
                res = _shape_dims(op.result_type)
                res_n = 1
                for d in (res[0] if res else []):
                    res_n *= d
                lhs_t = _operand_type(comp, comps, op.operands[0]) if op.operands else ""
                lhs_dims = (_shape_dims(lhs_t) or [[]])[0]
                m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
                k = 1
                if m and m.group(1):
                    for di in m.group(1).split(","):
                        if int(di) < len(lhs_dims):
                            k *= lhs_dims[int(di)]
                stats.flops += 2.0 * res_n * k
                stats.dots += 1
                if not inside_fusion:
                    stats.hbm_bytes += (_shape_bytes(op.result_type) + sum(
                        _shape_bytes(_operand_type(comp, comps, on))
                        for on in op.operands))
                continue
            if kind == "convolution":
                stats.convs += 1
                # rough: 2 * prod(result) * prod(kernel spatial+in-features)
                res = _shape_dims(op.result_type)
                res_n = 1
                for d in (res[0] if res else []):
                    res_n *= d
                rhs_t = _operand_type(comp, comps, op.operands[1]) if len(op.operands) > 1 else ""
                rhs_dims = (_shape_dims(rhs_t) or [[]])[0]
                k = 1
                for d in rhs_dims[:-1]:
                    k *= d
                stats.flops += 2.0 * res_n * k
                if not inside_fusion:
                    stats.hbm_bytes += _shape_bytes(op.result_type)
                continue
            if kind == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                trips = trip_count(mc.group(1)) if mc else 1
                if mb:
                    sub = analyze_comp(mb.group(1), inside_fusion=inside_fusion)
                    stats.add(sub, scale=trips)
                    stats.whiles.append((mb.group(1), trips))
                continue
            if kind == "conditional":
                branches = re.findall(r"%([\w\.\-]+)", op.attrs)
                subs = [analyze_comp(b, inside_fusion=inside_fusion)
                        for b in branches if b in comps]
                if subs:
                    biggest = max(subs, key=lambda s: s.flops + s.hbm_bytes)
                    stats.add(biggest)
                continue
            if kind in FUSION_LIKE:
                mcalls = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
                callee = comps.get(mcalls.group(1)) if mcalls else None
                if callee is not None:
                    sub = analyze_comp(callee.name, inside_fusion=True)
                    stats.add(sub)  # dots/colls inside the fusion
                if not inside_fusion:
                    stats.hbm_bytes += _fusion_traffic(comp, comps, op, callee)
                continue
            if kind in CALL_LIKE:
                mcalls = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", op.attrs)
                if mcalls:
                    sub = analyze_comp(mcalls.group(1), inside_fusion=inside_fusion)
                    stats.add(sub)
                if not inside_fusion:
                    stats.hbm_bytes += (_shape_bytes(op.result_type) + sum(
                        _shape_bytes(_operand_type(comp, comps, on))
                        for on in op.operands))
                continue
            # other top-level ops that move memory
            if not inside_fusion:
                if kind in ("tuple", "get-tuple-element", "bitcast", "reshape",
                            "parameter", "constant", "after-all"):
                    continue  # views / no traffic
                res = _shape_bytes(op.result_type)
                if kind == "dynamic-update-slice":
                    upd = _shape_bytes(
                        _operand_type(comp, comps, op.operands[1])
                        if len(op.operands) > 1 else "")
                    stats.hbm_bytes += 2 * upd  # in-place
                elif kind in ("dynamic-slice", "slice", "gather", "pad",
                              "broadcast", "iota", "reverse", "concatenate",
                              "transpose", "copy", "copy-start"):
                    stats.hbm_bytes += 2 * res  # reads ≈ writes ≈ result
                else:
                    stats.hbm_bytes += res + sum(
                        _shape_bytes(_operand_type(comp, comps, on))
                        for on in op.operands)
        return stats

    entry = _entry_name(text, comps)
    return analyze_comp(entry, inside_fusion=False)


# Backwards-compatible wrapper used by dryrun/benchmarks
@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def collective_bytes(text: str) -> CollectiveStats:
    st = analyze(text)
    return CollectiveStats(bytes_by_kind=st.coll_bytes, count_by_kind=st.coll_count)


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

#: TPU v5e-class hardware constants (per chip), per the assignment.
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class Roofline:
    """Three-term roofline.  Inputs are PER-DEVICE (the partitioned module),
    which equals global/chips — so the assignment's `X/(chips·rate)` formulas
    reduce to `x_dev/rate`."""
    flops: float        # per-device FLOPs per step
    hbm_bytes: float    # per-device HBM traffic per step
    coll_bytes: float   # per-device collective operand bytes per step
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of roofline: useful-compute time / bound time."""
        return self.compute_s / self.bound_s if self.bound_s else 0.0

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "compute_fraction": self.compute_fraction,
        }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n_active = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token/sequence


def active_params(cfg) -> float:
    """Parameters active per token (routed experts scaled by top_k/E)."""
    import jax
    import jax.tree_util as jtu
    from repro.models import build_model

    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = 0.0
    moe = cfg.moe
    for path, leaf in jtu.tree_flatten_with_path(shapes)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        p = jtu.keystr(path)
        if moe is not None and "moe" in p and ("'wi'" in p or "'wo'" in p):
            n = n * moe.top_k / moe.num_experts
        total += n
    return total


def total_params(cfg) -> float:
    import jax
    from repro.models import build_model

    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = 0
    for leaf in jax.tree.leaves(shapes):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return float(total)


__all__ = [
    "analyze", "HloStats", "parse_module",
    "collective_bytes", "CollectiveStats", "Roofline",
    "model_flops", "active_params", "total_params",
    "PEAK_FLOPS", "HBM_BW", "ICI_BW", "COLLECTIVE_KINDS",
]
