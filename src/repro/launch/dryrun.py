import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements of this module — JAX locks
the device count at first init, and the production meshes need 512 host
placeholder devices.  (Do not import this module from tests/benches.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --multi-pod --out out.json

Per cell this prints/records: memory_analysis (proves it fits),
cost_analysis FLOPs/bytes, the collective schedule (bytes by kind, loop-aware)
and the three roofline terms.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import SHAPES, cell_is_runnable, get_config, list_archs
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, rules_for
from repro.launch.specs import build_cell
from repro.sharding import use_rules


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             grad_sync: str = "gspmd", rules_override=None,
             cfg_overrides: dict | None = None, rules_updates: dict | None = None,
             save_hlo: str | None = None, tag: str = "", accum_steps: int = 1) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "tag": tag,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "why": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rules = rules_override or rules_for(cfg, shape)
    if rules_updates:
        rules = dict(rules, **rules_updates)
    t0 = time.time()
    with use_rules(mesh, rules) as R:
        step, args, _ = build_cell(cfg, shape, R, grad_sync=grad_sync,
                                   accum_steps=accum_steps)
        lowered = jax.jit(step).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(text)
    # loop-aware per-device analysis (cost_analysis ignores while trip counts)
    stats = hlo_analysis.analyze(text)
    flops = stats.flops
    hbm_bytes = stats.hbm_bytes
    roof = hlo_analysis.Roofline(flops=flops, hbm_bytes=hbm_bytes,
                                 coll_bytes=stats.total_coll_bytes, chips=chips)
    coll = stats
    mflops = hlo_analysis.model_flops(cfg.replace(dtype="bfloat16",
                                                  param_dtype="bfloat16"), shape)
    rec = {
        "arch": arch,
        "tag": tag,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "status": "ok",
        "grad_sync": grad_sync,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "bytes_per_device": {
            "peak": int(mem.peak_memory_in_bytes),
            "arguments": int(mem.argument_size_in_bytes),
            "output": int(mem.output_size_in_bytes),
            "temp": int(mem.temp_size_in_bytes),
        },
        "hlo_flops": flops,
        "hlo_bytes": hbm_bytes,
        "collectives": {
            "bytes_by_kind": coll.coll_bytes,
            "count_by_kind": coll.coll_count,
            "total_bytes": coll.total_coll_bytes,
        },
        "xla_cost_flops_per_dev": float(ca.get("flops", 0.0)),
        "model_flops": mflops,
        "useful_flops_ratio": (mflops / (flops * chips)) if flops else None,
        "roofline": roof.as_dict(),
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {sorted(SHAPES)} or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--grad-sync", default="gspmd", choices=["gspmd", "rma_ring"])
    ap.add_argument("--out", default=None, help="append JSON records here")
    ap.add_argument("--save-hlo", default=None, help="write compiled HLO here")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="model-config override, e.g. --set attn_impl=stub")
    ap.add_argument("--rule", action="append", default=[], metavar="NAME=AXES",
                    help="sharding-rule override, e.g. --rule seq=model or "
                         "--rule batch=pod,data,model or --rule embed=none")
    ap.add_argument("--tag", default="", help="label recorded with results")
    ap.add_argument("--accum", type=int, default=1, help="grad-accum microbatches")
    args = ap.parse_args(argv)

    def parse_v(v):
        if v.lower() in ("true", "false"):
            return v.lower() == "true"
        try:
            return int(v)
        except ValueError:
            try:
                return float(v)
            except ValueError:
                return v
    cfg_overrides = dict(kv.split("=", 1) for kv in args.set)
    cfg_overrides = {k: parse_v(v) for k, v in cfg_overrides.items()}
    rules_updates = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        if v.lower() in ("none", ""):
            rules_updates[k] = None
        elif "," in v:
            rules_updates[k] = tuple(v.split(","))
        else:
            rules_updates[k] = v

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = sorted(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records, failures = [], 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   grad_sync=args.grad_sync,
                                   cfg_overrides=cfg_overrides or None,
                                   rules_updates=rules_updates or None,
                                   save_hlo=args.save_hlo, tag=args.tag,
                                   accum_steps=args.accum)
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                records.append(rec)
                status = rec["status"]
                if status == "ok":
                    r = rec["roofline"]
                    print(f"[dryrun] {tag}: OK peak={rec['bytes_per_device']['peak']/2**30:.2f}GiB/dev "
                          f"flops/dev={rec['hlo_flops']:.3g} coll/dev={rec['collectives']['total_bytes']:.3g}B "
                          f"dominant={r['dominant']} "
                          f"(c={r['compute_s']*1e3:.2f}ms m={r['memory_s']*1e3:.2f}ms "
                          f"n={r['collective_s']*1e3:.2f}ms) "
                          f"compile={rec['compile_s']}s", flush=True)
                elif status == "skipped":
                    print(f"[dryrun] {tag}: SKIP ({rec['why']})", flush=True)
                else:
                    print(f"[dryrun] {tag}: FAILED {rec['error']}", flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    print(f"[dryrun] done: {len(records)} cells, {failures} failures", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
