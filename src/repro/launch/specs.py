"""ShapeDtypeStruct stand-ins and step builders for every dry-run cell.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable
ShapeDtypeStructs for every model input — no device allocation, exactly the
shannon/kernels pattern.  ``build_cell`` assembles the (step_fn, arg_specs)
pair that ``dryrun.py`` lowers and compiles.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.sharding import ShardingRules, spec_to_sharding
from repro.train.optimizer import OptimizerConfig, init_opt_state, opt_state_specs
from repro.train.trainstep import make_train_step

SDS = jax.ShapeDtypeStruct


def _sds_like(shape_dtype_tree, sharding_tree):
    return jax.tree.map(
        lambda l, s: SDS(l.shape, l.dtype, sharding=s),
        shape_dtype_tree, sharding_tree)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules) -> dict:
    """ShapeDtypeStructs for the data batch of this cell."""
    B, S = shape.global_batch, shape.seq_len
    bsh = rules.sharding(("batch", None))
    specs: dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = SDS((B, S), jnp.int32, sharding=bsh)
        specs["labels"] = SDS((B, S), jnp.int32, sharding=bsh)
    elif shape.kind == "prefill":
        specs["tokens"] = SDS((B, S), jnp.int32, sharding=bsh)
    else:  # decode: one new token against a seq_len cache
        specs["tokens"] = SDS((B, 1), jnp.int32, sharding=bsh)
    if cfg.enc_layers and shape.kind != "decode":
        specs["frames"] = SDS((B, S, cfg.d_model), cfg.activation_dtype,
                              sharding=rules.sharding(("batch", None, None)))
    if cfg.vlm_prefix and shape.kind != "decode":
        specs["patches"] = SDS((B, cfg.vlm_prefix, cfg.d_model),
                               cfg.activation_dtype,
                               sharding=rules.sharding(("batch", None, None)))
    return specs


def input_specs(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules) -> dict:
    """Public name required by the assignment: the model-input stand-ins."""
    return batch_specs(cfg, shape, rules)


def param_specs_sds(model, rules: ShardingRules):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    shardings = spec_to_sharding(model.param_specs(), rules)
    return _sds_like(shapes, shardings), shardings


def opt_specs_sds(model, params_sds, rules: ShardingRules):
    shapes = jax.eval_shape(init_opt_state, params_sds)
    shardings = spec_to_sharding(
        opt_state_specs(model.param_specs()), rules)
    return _sds_like(shapes, shardings), shardings


def cache_specs_sds(model, shape: ShapeConfig, rules: ShardingRules,
                    enc_len: int = 0):
    cfg = model.cfg
    B = shape.global_batch
    shapes = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len, cfg.activation_dtype,
                                 enc_len=enc_len))
    shardings = spec_to_sharding(model.cache_specs(), rules)
    return _sds_like(shapes, shardings), shardings


def build_cell(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules,
               *, grad_sync: str = "gspmd", accum_steps: int = 1):
    """Return (step_fn, args_sds tuple, out_shardings or None) for this cell.

    train:   step(params, opt_state, batch)
    prefill: step(params, batch, cache)
    decode:  step(params, cache, tokens)
    """
    # production numerics: bf16 params+compute, fp32 optimizer moments
    cfg = cfg.replace(dtype="bfloat16", param_dtype="bfloat16")
    model = build_model(cfg)
    enc_len = shape.seq_len if cfg.enc_layers else 0

    params_sds, param_sh = param_specs_sds(model, rules)
    if shape.kind == "train":
        opt_sds, opt_sh = opt_specs_sds(model, params_sds, rules)
        batch = batch_specs(cfg, shape, rules)
        step = make_train_step(model, OptimizerConfig(), grad_sync=grad_sync,
                               accum_steps=accum_steps)
        return step, (params_sds, opt_sds, batch), None
    if shape.kind == "prefill":
        cache_sds, cache_sh = cache_specs_sds(model, shape, rules, enc_len)
        batch = batch_specs(cfg, shape, rules)

        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)

        return prefill_step, (params_sds, batch, cache_sds), None
    # decode
    cache_sds, cache_sh = cache_specs_sds(model, shape, rules, enc_len)
    batch = batch_specs(cfg, shape, rules)

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return serve_step, (params_sds, cache_sds, batch["tokens"]), None


__all__ = [
    "input_specs", "batch_specs", "build_cell",
    "param_specs_sds", "opt_specs_sds", "cache_specs_sds",
]
