"""Serving launcher: continuous batching over any registered architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tiny \
      --requests 8 --max-new 16

Disaggregated mode (the RMA serving data plane, ``docs/serving_disagg.md``):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --disagg

runs the decode engine on the **paged KV pool** (page-table indirection,
page alloc/free at slot admit/release) and first drives the 8-fake-device
prefill→push→doorbell→admission→decode round trip through memory handles in
a subprocess.  ``--disagg --dry-run`` runs only that round trip.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.tiny import tiny_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def run_disagg_demo() -> None:
    """The SPMD round trip needs 8 fake devices, which must be configured
    before JAX initializes — run it as a subprocess."""
    import repro

    env = dict(os.environ)
    # fake host devices only multiply the CPU backend: pin the subprocess to
    # it (the demo is a semantics check, not a perf run) and keep whatever
    # XLA flags the user already set
    env["JAX_PLATFORMS"] = "cpu"
    flags = "--xla_force_host_platform_device_count=8"
    prev_flags = env.get("XLA_FLAGS")
    env["XLA_FLAGS"] = f"{prev_flags} {flags}" if prev_flags else flags
    # the subprocess must import repro from wherever *this* process found it
    # (cwd-independent — "src" only exists relative to the repo root)
    src_root = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    prev = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src_root + (os.pathsep + prev if prev else "")
    proc = subprocess.run([sys.executable, "-m", "repro.serve.disagg"],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    print(proc.stdout, end="")
    if proc.returncode != 0:
        print(proc.stderr)
        raise SystemExit("disagg round-trip demo failed")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated mode: paged-KV decode engine + the "
                         "prefill→decode handle-path round-trip demo")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="tokens per KV page in --disagg mode")
    ap.add_argument("--policy", default="continuous",
                    choices=["continuous", "static", "priority", "fair"],
                    help="admission policy: continuous batching (default), "
                         "static whole-batch, priority, or fair-share")
    ap.add_argument("--prefix-share", action="store_true",
                    help="COW KV prefix sharing on the paged pool "
                         "(requires --disagg); requests with a common "
                         "prompt prefix map the same physical pages")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="cap the allocatable physical KV pages below "
                         "slots*max_seq/page_tokens (admission backs off "
                         "under pool pressure)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="with --prefix-share: give every request the same "
                         "random prefix of this many tokens")
    ap.add_argument("--inject", default=None, metavar="SPEC",
                    help="elastic mode: drive the engine through "
                         "repro.ft.elastic with a scripted fault spec, e.g. "
                         "'slow:1@4x6,dead:1@8' (kind:worker@tick[xmag]; "
                         "kinds slow/dead/bell/rejoin) or 'random:SEED'")
    ap.add_argument("--workers", type=int, default=2,
                    help="with --inject: decode slots are owned "
                         "n_slots//workers per worker; evicting a worker "
                         "drains and requeues its slots")
    ap.add_argument("--dry-run", action="store_true",
                    help="with --disagg: run only the round-trip demo")
    args = ap.parse_args(argv)

    if args.dry_run and not args.disagg:
        ap.error("--dry-run requires --disagg")
    if args.prefix_share and not args.disagg:
        ap.error("--prefix-share requires --disagg (the paged pool)")
    if args.disagg:
        run_disagg_demo()
        if args.dry_run:
            return

    cfg = tiny_config(args.arch) if args.tiny else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    enc_len = args.prompt_len if cfg.enc_layers else 0
    eng = ServeEngine(model, params, n_slots=args.slots, max_seq=args.max_seq,
                      enc_len=enc_len, paged_kv=args.disagg,
                      page_tokens=args.page_tokens, policy=args.policy,
                      prefix_share=args.prefix_share, kv_pages=args.kv_pages)
    rng = np.random.RandomState(args.seed)
    shared = rng.randint(0, cfg.vocab, size=args.shared_prefix_len)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        tail = max(args.prompt_len - args.shared_prefix_len, 1)
        prompt = np.concatenate([shared, rng.randint(0, cfg.vocab, size=tail)])
        eng.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.max_new))
    es = None
    if args.inject is not None:
        from repro.ft.elastic import ElasticServing
        from repro.ft.inject import FaultScript
        if args.inject.startswith("random:"):
            script = FaultScript.random(int(args.inject.split(":", 1)[1]),
                                        n_workers=args.workers)
        else:
            script = FaultScript.parse(args.inject)
        es = ElasticServing(eng, script, n_workers=args.workers)
        done = es.run()
    else:
        done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(c.tokens) for c in done)
    mode = "disagg/paged" if args.disagg else "dense"
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {args.slots} slots, {mode} KV, "
          f"{args.policy} admission)")
    if es is not None:
        st = es.stats()
        print(f"[serve] elastic: workers={st['elastic']['workers']} "
              f"evictions={st['evictions']} "
              f"faults={st['faults_injected']} "
              f"offline_slots={st['offline_slots']}")
    if args.disagg:
        print(f"[serve] pool stats: {eng.stats()}")
    for c in sorted(done, key=lambda c: c.rid)[:3]:
        print(f"[serve]   rid={c.rid}: {c.tokens[:8]}...")


if __name__ == "__main__":
    main()
