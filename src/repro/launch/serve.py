"""Serving launcher: continuous batching over any registered architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tiny \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.tiny import tiny_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = tiny_config(args.arch) if args.tiny else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    enc_len = args.prompt_len if cfg.enc_layers else 0
    eng = ServeEngine(model, params, n_slots=args.slots, max_seq=args.max_seq,
                      enc_len=enc_len)
    rng = np.random.RandomState(args.seed)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        eng.submit(Request(rid=rid,
                           prompt=rng.randint(0, cfg.vocab, size=args.prompt_len),
                           max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(c.tokens) for c in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {args.slots} slots)")
    for c in sorted(done, key=lambda c: c.rid)[:3]:
        print(f"[serve]   rid={c.rid}: {c.tokens[:8]}...")


if __name__ == "__main__":
    main()
