"""Training launcher: data → step → checkpoint/restart → straggler watch.

Runs real training on whatever devices exist (CPU for examples/tests, a TPU
slice in production — the mesh adapts).  Fault-tolerance behaviours:

* periodic async checkpoints (atomic, retained K);
* ``--resume`` restores the latest complete checkpoint **and** the data
  pipeline position (deterministic counter-based batches);
* a straggler monitor EMA-watches step times; chronic stragglers raise (the
  cluster layer restarts the job on a healthy slice — simulated in tests);
* simulated failure injection (``--fail-at-step``) for the restart test.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --tiny \
      --steps 200 --ckpt-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.tiny import tiny_config
from repro.data.pipeline import DataConfig, make_source
from repro.ft.straggler import StragglerMonitor
from repro.models import build_model
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.trainstep import make_train_step


@dataclasses.dataclass
class TrainRun:
    """Result record for tests/examples."""
    steps_run: int
    final_step: int
    losses: list
    straggler_events: int


def train(arch: str, *, tiny: bool = True, steps: int = 100,
          global_batch: int = 8, seq_len: int = 64,
          ckpt_dir: str | None = None, ckpt_every: int = 50,
          resume: bool = False, fail_at_step: int | None = None,
          peak_lr: float = 3e-3, log_every: int = 10,
          data_seed: int = 0, mesh=None, grad_sync: str = "gspmd",
          moe_ep: str | None = None) -> TrainRun:
    cfg = tiny_config(arch) if tiny else get_config(arch)
    model = build_model(cfg)
    opt_cfg = OptimizerConfig(peak_lr=peak_lr, warmup_steps=min(20, steps // 5),
                              total_steps=steps)
    data = make_source(DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                                  global_batch=global_batch, seed=data_seed))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    start_step = 0

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if resume and mgr is not None:
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            print(f"[train] resumed from step {latest}", flush=True)

    step_fn = jax.jit(make_train_step(model, opt_cfg, grad_sync=grad_sync,
                                      moe_ep=moe_ep))
    monitor = StragglerMonitor(threshold=3.0)
    losses = []

    # moe_ep="rma" dispatches through shard_map over the expert axis, which
    # only exists while sharding rules are active — without this the flag
    # would silently trace the degenerate single-device path on a multi-
    # device host.  Rules stay scoped to this run's tracing.
    rules_ctx = contextlib.nullcontext()
    if moe_ep == "rma":
        from repro import compat, sharding

        n_dev = len(jax.devices())
        if n_dev > 1 and cfg.moe is not None and cfg.moe.num_experts % n_dev == 0:
            rules_ctx = sharding.use_rules(compat.make_mesh((n_dev,), ("model",)))
            print(f"[train] moe_ep=rma: expert axis over {n_dev} devices",
                  flush=True)
        else:
            print(f"[train] moe_ep=rma: single-device fallback "
                  f"({n_dev} devices, {cfg.moe.num_experts if cfg.moe else 0} "
                  "experts)", flush=True)

    with rules_ctx:
        return _train_loop(start_step, steps, data, step_fn, params, opt_state,
                           monitor, losses, mgr, ckpt_every, fail_at_step,
                           log_every)


def _train_loop(start_step, steps, data, step_fn, params, opt_state, monitor,
                losses, mgr, ckpt_every, fail_at_step, log_every) -> TrainRun:
    for step in range(start_step, steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        if fail_at_step is not None and step == fail_at_step:
            if mgr is not None:
                # the preemption notice's grace period: let the in-flight
                # async checkpoint land before the process dies, so the
                # latest completed save is durable
                mgr.wait()
            raise RuntimeError(f"simulated preemption at step {step}")
        monitor.start()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        monitor.stop(step)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step={step} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
    if mgr is not None:
        mgr.save(steps, {"params": params, "opt": opt_state}, blocking=True)
    return TrainRun(steps_run=steps - start_step, final_step=steps,
                    losses=losses, straggler_events=len(monitor.events))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    ap.add_argument("--moe-ep", choices=("gspmd", "rma"), default=None,
                    help="MoE expert-parallel dispatch: partitioner all-to-all"
                         " (gspmd) or the one-sided RMA token exchange (rma)")
    args = ap.parse_args(argv)
    run = train(args.arch, tiny=args.tiny, steps=args.steps,
                global_batch=args.global_batch, seq_len=args.seq_len,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                resume=args.resume, fail_at_step=args.fail_at_step,
                peak_lr=args.peak_lr, moe_ep=args.moe_ep)
    print(f"[train] done: loss {run.losses[0]:.4f} -> {run.losses[-1]:.4f}, "
          f"stragglers={run.straggler_events}")


if __name__ == "__main__":
    main()
