"""Production meshes and per-(arch × shape) sharding rules.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — required because the
dry-run forces a 512-device host platform while tests/benches run on 1.
"""
from __future__ import annotations

from repro.compat import make_mesh
from repro.configs.base import ModelConfig, ShapeConfig
from repro.sharding import DEFAULT_RULES


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2×16×16 = 512 chips for the two-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host (CPU) devices for tests/examples."""
    return make_mesh((data, model), ("data", "model"))


def mesh_topology(mesh, axis: str):
    """The ``g hosts × l local`` factorization of one mesh axis, or ``None``
    for the flat treatment.

    Thin launch-layer hook over :func:`repro.core.rma.topology_from_mesh`:
    multi-host meshes are grouped by ``process_index``; single-process
    (simulated) meshes honor the ``RMA_TOPOLOGY=GxL`` environment override.
    Feed the result to ``make_train_step(topology=…)``,
    ``plan_all_reduce`` / ``plan_all_to_all``, or ``RmaPlan(topology=…)``
    so compiled plans use the hierarchical inter/intra-node lowering."""
    from repro.core.rma.topology import topology_from_mesh

    return topology_from_mesh(mesh, axis)


MODEL_AXIS_SIZE = 16  # both production meshes have model=16


def rules_for(cfg: ModelConfig, shape: ShapeConfig, *, fsdp: bool = True) -> dict:
    """Logical→mesh mapping for one dry-run cell (the GSPMD baseline).

    * batch        → ("pod", "data")            (DP across pods and data axis)
    * heads/mlp/vocab/expert → "model"          (TP / EP), *only when the
      dimension divides the model-axis size* — e.g. whisper's 8 heads or
      llama4's 40 heads cannot 16-way shard, so those weights stay TP-
      replicated and FSDP carries them (documented per-arch in DESIGN.md).
    * params' "embed" dim → ("pod","data")      (ZeRO-3/FSDP; activations'
      embed name is consumed by batch first, so they stay data-sharded only)
    * decode shapes: the KV cache's seq dim shards over "model"
      (flash-decode style partial attention) — kv head counts (often 8) do
      not divide 16, and the cache is the dominant allocation.
    * long_500k (batch=1): batch unsharded; cache seq shards over
      ("data","model"); params TP-only.
    """
    m = MODEL_AXIS_SIZE
    rules = dict(DEFAULT_RULES)
    rules["batch"] = ("pod", "data")
    rules["heads"] = "model" if cfg.n_heads % m == 0 else None
    rules["kv_heads"] = "model" if cfg.n_kv_heads % m == 0 else None
    rules["vocab"] = "model"  # vocab_padded is a multiple of 256
    rules["expert"] = "model" if (cfg.moe and cfg.moe.num_experts % m == 0) else None
    # the fused mlp dim must divide for every projection that carries it
    mlp_dims = {2 * cfg.d_ff, cfg.d_ff} if cfg.d_ff else set()
    if cfg.ssm is not None:
        d_inner = cfg.ssm.expand * cfg.d_model
        nheads = d_inner // cfg.ssm.headdim
        mlp_dims |= {2 * d_inner + 2 * cfg.ssm.d_state + nheads,
                     d_inner + 2 * cfg.ssm.d_state, d_inner}
    if cfg.moe is not None:
        mlp_dims |= {2 * cfg.moe.d_ff_shared, cfg.moe.d_ff_shared} - {0}
    rules["mlp"] = "model" if all(d % m == 0 for d in mlp_dims) else None
    if fsdp:
        rules["embed"] = ("pod", "data")
    if shape.kind == "decode":
        rules["kv_seq"] = "model"
    if shape.name == "long_500k":
        rules["batch"] = None
        rules["kv_seq"] = ("data", "model")
        rules["embed"] = None  # batch=1: params TP-only, data carries the cache
    return rules


__all__ = ["make_production_mesh", "make_host_mesh", "mesh_topology",
           "rules_for"]
