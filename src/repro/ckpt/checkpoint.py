"""Checkpointing: atomic, async, resharding-on-restore, retention.

Design for thousands of nodes:

* every host writes only its own shards (here: one host writes all, but the
  layout is per-shard files keyed by flattened tree path);
* a checkpoint directory is staged under ``<step>.tmp`` and atomically
  renamed to ``<step>`` once the manifest is fsync'd — a crashed save can
  never be mistaken for a complete one;
* saves run on a background thread (training continues; ``wait()`` joins);
* ``restore`` reshards: arrays are loaded on host and ``device_put`` with the
  *current* mesh/sharding — the elastic-scaling path (a checkpoint written on
  a 16-host data axis restores onto 8 or 32);
* retention keeps the newest K checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path).replace("/", "_")
        out.append((name, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- save -------------------------------------------------------------------
    def save(self, step: int, state, *, blocking: bool = False) -> None:
        """Snapshot ``state`` (any pytree) at ``step``.  Async by default."""
        self.wait()
        # materialize on host NOW (so training can mutate device buffers)
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _write():
            try:
                tmp = os.path.join(self.dir, f"{step}.tmp")
                final = os.path.join(self.dir, str(step))
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                named, _ = _flatten_with_names(host_state)
                manifest = {"step": step, "leaves": []}
                for i, (name, leaf) in enumerate(named):
                    fn = f"leaf_{i:05d}.npy"
                    np.save(os.path.join(tmp, fn), leaf)
                    manifest["leaves"].append(
                        {"name": name, "file": fn,
                         "shape": list(leaf.shape), "dtype": str(leaf.dtype)})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):  # step already committed: idempotent
                    shutil.rmtree(tmp)
                else:
                    os.rename(tmp, final)  # atomic commit
                self._retain()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore ------------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = [int(d) for d in os.listdir(self.dir) if re.fullmatch(r"\d+", d)
                 and os.path.exists(os.path.join(self.dir, d, "manifest.json"))]
        return max(steps) if steps else None

    def restore(self, step: int, like, *, shardings=None):
        """Load checkpoint ``step`` into the structure of ``like``.

        ``shardings``: optional matching tree of NamedShardings — arrays are
        placed onto the *current* mesh (elastic restore)."""
        d = os.path.join(self.dir, str(step))
        if not os.path.exists(os.path.join(d, "manifest.json")):
            steps = sorted(
                int(s) for s in os.listdir(self.dir)
                if re.fullmatch(r"\d+", s)
                and os.path.exists(os.path.join(self.dir, s, "manifest.json")))
            raise FileNotFoundError(
                f"checkpoint step {step} not found in {self.dir} "
                f"(available steps: {steps if steps else 'none'})")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        if len(manifest["leaves"]) != len(flat_like):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"model expects {len(flat_like)}")
        leaves = []
        shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                      if shardings is not None else [None] * len(flat_like))
        for meta, ref, sh in zip(manifest["leaves"], flat_like, shard_flat):
            arr = np.load(os.path.join(d, meta["file"]))
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"{meta['name']}: shape {arr.shape} != expected {ref.shape}")
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jnp.asarray(arr, dtype=ref.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # -- retention ------------------------------------------------------------------
    def _retain(self) -> None:
        steps = sorted(
            (int(d) for d in os.listdir(self.dir) if re.fullmatch(r"\d+", d)),
            reverse=True)
        for s in steps[self.keep:]:
            shutil.rmtree(os.path.join(self.dir, str(s)), ignore_errors=True)


__all__ = ["CheckpointManager"]
