"""Elastic runtime — quarantine, plan recompilation, live KV-page migration.

The paper's declaration thesis pays off twice when a worker fails: because
topology is a *declared plan input* with fingerprinted build-once caches
(``core/rma/topology.py``, PR 6), reacting to a mesh change is a targeted
cache invalidation plus ~1.4 ms rebuilds — not a global teardown; and
because KV pages live behind memory handles with epoch-checked lifetimes
(P5, PR 3/9), a victim's pages can be migrated to survivors while racing
reads come back **zero-masked and counted**, never as reused bytes.  foMPI
(Gerstenberger et al., PAPERS.md) is the reference discipline: recovery
cost must be O(affected peers), not O(mesh).

Three pieces:

* :class:`ElasticController` — the control plane.  Consumes
  :class:`~repro.ft.straggler.StragglerMonitor` escalations and injected
  faults (:mod:`repro.ft.inject`) and drives each worker through the
  lifecycle ::

      healthy -> suspect -> quarantined -> evicted -> rejoined -> healthy

  On eviction it re-derives the shrunken :class:`Topology`, drops exactly
  the cached plans whose fingerprint died
  (:func:`repro.core.rma.plan.invalidate_topology`), and runs the caller's
  ``rebuild`` / ``migrate`` / ``on_evict`` hooks — every recovery is
  written up as a :class:`RecoveryReport`.
* :func:`migrate_pages` — the data plane: a victim's live pages pushed to
  survivors as one batched memhandle ``put_handle`` replay on a dedicated
  migration stream (:data:`MIGRATION_STREAM`), reusing the PR 9 transfer
  plan and its stale-epoch machinery unchanged.
* :class:`ElasticServing` — glue binding an injector + controller to a
  :class:`~repro.serve.engine.ServeEngine`: a quarantined worker's slots
  are drained, its in-flight sequences re-admitted through scheduler
  ``requeue`` (re-prefill makes the drained tokens bit-identical to a
  fault-free run), and its unclaimed fetch_op tickets released so the
  admission window never leaks.

See ``docs/elastic.md`` for the state machine and the fault-injection
cookbook.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter
from typing import Callable

from repro.core.rma.plan import invalidate_topology, plan_cache_stats
from repro.core.rma.topology import Topology
from repro.ft.inject import Fault, FaultInjector, FaultScript
from repro.ft.straggler import StragglerEvent, StragglerMonitor

# -- lifecycle states --------------------------------------------------------
HEALTHY = "healthy"          # full member of the decode set
SUSPECT = "suspect"          # strikes accumulating, still serving
QUARANTINED = "quarantined"  # out of the decode set, grace for in-flight
EVICTED = "evicted"          # removed from the topology, recovery ran
REJOINED = "rejoined"        # back after eviction, on probation

LIFECYCLE = (HEALTHY, SUSPECT, QUARANTINED, EVICTED, REJOINED)

#: Stream victim-page migration rides on — distinct from the serving data
#: plane's push lanes (0/1) so recovery traffic neither shares a flush
#: epoch with nor serializes behind in-flight prefill pushes (the pool
#: windows declare ``max_streams=4``; the tier plans use 2/3 on the *host*
#: window, a different substrate).
MIGRATION_STREAM = 2


@dataclasses.dataclass
class WorkerState:
    worker: int
    state: str = HEALTHY
    strikes: int = 0
    since: int = 0             # tick of the last state change


@dataclasses.dataclass(frozen=True)
class Transition:
    worker: int
    frm: str
    to: str
    tick: int
    reason: str


@dataclasses.dataclass
class RecoveryReport:
    """One eviction's (or rejoin's) full recovery accounting."""

    worker: int
    tick: int
    reason: str
    old_topology: Topology
    new_topology: Topology
    plans_dropped: dict        # cache name -> dropped keys
    plans_rebuilt: int         # plans recompiled by the rebuild hook
    migration: dict            # migrate hook's stats (pages, peers, ...)
    requeued: int              # in-flight sequences re-admitted
    duration_s: float = 0.0

    @property
    def dropped_count(self) -> int:
        return sum(len(v) for v in self.plans_dropped.values())


def shrink_topology(topo: Topology, n_alive: int,
                    evicted=()) -> Topology:
    """The surviving mesh's declared factorization after eviction.

    When the evicted ranks cover whole hosts exactly (the common real
    failure: a host drops with all its local devices), the factorization
    survives with fewer hosts — ``Topology(g-k, l)``.  Any partial-host
    loss cannot tile host-major, so the survivors get the safe flat
    declaration ``Topology.flat(n_alive)`` rather than a wrong hierarchy."""
    if n_alive < 1:
        raise ValueError(f"cannot shrink to {n_alive} workers")
    g, l = topo.hosts, topo.local
    by_host = Counter(topo.host_of(int(w)) for w in set(evicted))
    if (by_host and all(c == l for c in by_host.values())
            and (g - len(by_host)) * l == n_alive):
        return Topology(g - len(by_host), l)
    return Topology.flat(n_alive)


def migrate_pages(pool, moves, perm, *, stream: int = MIGRATION_STREAM,
                  backend: str = "rma"):
    """Migrate a victim's live KV pages to survivor-owned slots.

    ``moves`` is a sequence of ``(src_page, dst_page)``: each source page's
    payload is read from the pool and the batch is pushed into the
    destination pages through their memory handles — one
    :meth:`~repro.serve.paged.PagedKVWindow.push_pages` compiled-plan
    replay on the dedicated migration stream (2 phases per page + 2 for
    the single exit epoch, so the transfer count is O(victim pages), never
    O(mesh)).  The destinations must already be ``alloc_page``'d by the
    receiver — that is the P5 handle exchange — and the *source* pages
    should be freed only **after** migration: the epoch bump then turns
    any read still racing the eviction into a zero-masked, counted drop.

    Returns ``(pool, n_pages_moved)``."""
    moves = [(int(s), int(d)) for s, d in moves]
    if not moves:
        return pool, 0
    kvs = [pool.read_page(s) for s, _ in moves]
    pool = pool.push_pages([d for _, d in moves], kvs, perm, stream=stream,
                           backend=backend)
    return pool, len(moves)


class ElasticController:
    """The elastic control plane over ``n_workers`` ranks.

    Inputs: per-step durations (:meth:`observe_step` feeds the straggler
    monitor; its escalations strike the source worker), transport events
    (:meth:`note_lost_doorbell`), and scripted faults (:meth:`apply_fault`).
    :meth:`advance` runs the per-tick state machine — quarantine grace
    expiry triggers the recovery pipeline, probation expiry re-promotes a
    rejoined worker.

    Recovery hooks (all optional):

    * ``rebuild(new_topology, dropped) -> int`` — recompile plans for the
      surviving mesh; returns how many were rebuilt.
    * ``migrate(worker, new_topology) -> dict`` — move the victim's KV
      pages; returns stats (e.g. ``{"pages": 4, "peers": 1}``).
    * ``on_evict(worker) -> int`` — drain/re-admit the victim's in-flight
      sequences; returns how many were requeued.
    * ``on_rejoin(worker)`` — re-enable the worker's resources.
    * ``on_transition(Transition)`` — observability tap for every edge.
    """

    def __init__(self, n_workers: int, *, topology: Topology | None = None,
                 monitor: StragglerMonitor | None = None,
                 suspect_strikes: int = 2, quarantine_grace: int = 1,
                 probation: int = 3,
                 rebuild: Callable | None = None,
                 migrate: Callable | None = None,
                 on_evict: Callable | None = None,
                 on_rejoin: Callable | None = None,
                 on_transition: Callable | None = None):
        if n_workers < 2:
            raise ValueError("elastic control needs n_workers >= 2 "
                             "(eviction must leave a survivor)")
        self.n_workers = n_workers
        self.topology = topology if topology is not None \
            else Topology.flat(n_workers)
        if self.topology.axis_size != n_workers:
            raise ValueError(
                f"topology {self.topology} declares "
                f"{self.topology.axis_size} ranks, got n_workers={n_workers}")
        self.monitor = monitor if monitor is not None else StragglerMonitor(
            threshold=2.0, warmup_steps=2, escalate_after=2)
        self.monitor.on_escalate = self._on_escalate
        self.suspect_strikes = suspect_strikes
        self.quarantine_grace = quarantine_grace
        self.probation = probation
        self.rebuild = rebuild
        self.migrate = migrate
        self.on_evict = on_evict
        self.on_rejoin = on_rejoin
        self.on_transition = on_transition
        self.workers = {w: WorkerState(w) for w in range(n_workers)}
        self.transitions: list[Transition] = []
        self.reports: list[RecoveryReport] = []
        self._tick = 0

    # -- identity helpers -----------------------------------------------------
    @staticmethod
    def source_of(worker: int) -> str:
        """The monitor/scheduler source key for a worker rank."""
        return f"worker{worker}"

    def state_of(self, worker: int) -> str:
        return self.workers[worker].state

    def alive(self) -> list[int]:
        """Ranks still in the topology (everything but evicted)."""
        return [w for w, ws in self.workers.items() if ws.state != EVICTED]

    def serving(self) -> list[int]:
        """Ranks in the decode set (healthy / suspect / on probation)."""
        return [w for w, ws in self.workers.items()
                if ws.state in (HEALTHY, SUSPECT, REJOINED)]

    # -- inputs ---------------------------------------------------------------
    def observe_step(self, worker: int, duration: float,
                     tick: int | None = None) -> StragglerEvent | None:
        """Feed one worker-step time; escalations strike the worker."""
        if tick is not None:
            self._tick = tick
        if self.workers[worker].state in (QUARANTINED, EVICTED):
            return None
        return self.monitor.observe(self._tick, duration,
                                    source=self.source_of(worker))

    def note_lost_doorbell(self, worker: int, tick: int | None = None) -> None:
        """A put_signal doorbell never landed (transport loss, RAMC-style):
        one suspect strike with no slow step involved."""
        if tick is not None:
            self._tick = tick
        self._strike(worker, "lost_doorbell")

    def apply_fault(self, fault: Fault, tick: int | None = None,
                    ) -> RecoveryReport | None:
        """React to one injected fault.  ``slow_step`` needs no direct
        action (it manifests through :meth:`observe_step` durations);
        ``dead_worker`` skips the grace period — there is nothing left to
        drain — and runs recovery immediately."""
        if tick is not None:
            self._tick = tick
        if fault.kind == "dead_worker":
            ws = self.workers[fault.worker]
            if ws.state == EVICTED:
                return None
            if ws.state != QUARANTINED:
                self._transition(fault.worker, QUARANTINED, "dead_worker")
            return self._evict(fault.worker, "dead_worker")
        if fault.kind == "lost_doorbell":
            self.note_lost_doorbell(fault.worker)
        elif fault.kind == "rejoin":
            self.rejoin(fault.worker)
        return None

    # -- per-tick state machine -----------------------------------------------
    def advance(self, tick: int) -> list[RecoveryReport]:
        """Run the tick's lifecycle edges: grace-expired quarantines evict
        (recovery pipeline), clean probations re-promote to healthy."""
        self._tick = tick
        reports = []
        for w, ws in list(self.workers.items()):
            if (ws.state == QUARANTINED
                    and tick - ws.since >= self.quarantine_grace):
                reports.append(self._evict(w, "quarantine_grace"))
            elif (ws.state == REJOINED
                    and tick - ws.since >= self.probation):
                self._transition(w, HEALTHY, "probation_clean")
        return reports

    def rejoin(self, worker: int) -> RecoveryReport | None:
        """Re-admit an evicted worker (probation).

        The monitor's memory of the worker is cleared
        (:meth:`StragglerMonitor.reset` with its source) — its pre-eviction
        offender count must not re-escalate it on the first slow step —
        and the topology re-expands, invalidating the shrunken mesh's
        plans exactly as eviction invalidated the old ones."""
        ws = self.workers[worker]
        if ws.state != EVICTED:
            return None
        self.monitor.reset(self.source_of(worker))
        self._transition(worker, REJOINED, "rejoin")
        ws.strikes = 0
        report = self._retopologize(worker, "rejoin", migrated={},
                                    requeued=0)
        if self.on_rejoin is not None:
            self.on_rejoin(worker)
        return report

    # -- internals -------------------------------------------------------------
    def _on_escalate(self, event: StragglerEvent) -> None:
        src = event.source
        if src.startswith("worker"):
            try:
                self._strike(int(src[len("worker"):]),
                             f"straggler x{event.ratio:.1f}")
            except ValueError:
                pass

    def _strike(self, worker: int, reason: str) -> None:
        ws = self.workers[worker]
        if ws.state in (QUARANTINED, EVICTED):
            return
        ws.strikes += 1
        if ws.state in (HEALTHY, REJOINED):
            self._transition(worker, SUSPECT, reason)
        if ws.strikes >= self.suspect_strikes:
            self._transition(worker, QUARANTINED,
                             f"{ws.strikes} strikes ({reason})")

    def _transition(self, worker: int, to: str, reason: str) -> None:
        ws = self.workers[worker]
        tr = Transition(worker, ws.state, to, self._tick, reason)
        ws.state, ws.since = to, self._tick
        self.transitions.append(tr)
        if self.on_transition is not None:
            self.on_transition(tr)

    def _evict(self, worker: int, reason: str) -> RecoveryReport:
        t0 = time.perf_counter()
        self._transition(worker, EVICTED, reason)
        requeued = self.on_evict(worker) if self.on_evict is not None else 0
        report = self._retopologize(worker, reason, requeued=requeued)
        report.duration_s = time.perf_counter() - t0
        return report

    def _retopologize(self, worker: int, reason: str, *,
                      migrated: dict | None = None,
                      requeued: int = 0) -> RecoveryReport:
        """The recovery pipeline shared by evict and rejoin: re-derive the
        topology, invalidate exactly the dead fingerprint's plans, then
        rebuild and migrate through the caller's hooks."""
        old = self.topology
        alive = self.alive()
        evicted = [w for w, ws in self.workers.items()
                   if ws.state == EVICTED]
        new = shrink_topology(old, len(alive), evicted) \
            if len(alive) < self.n_workers else Topology.flat(len(alive))
        dropped: dict = {}
        if new.fingerprint() != old.fingerprint():
            dropped = invalidate_topology(old.fingerprint())
        self.topology = new
        rebuilt = 0
        if self.rebuild is not None:
            rebuilt = int(self.rebuild(new, dropped) or 0)
        migration = migrated
        if migration is None:
            migration = dict(self.migrate(worker, new) or {}) \
                if self.migrate is not None else {}
        report = RecoveryReport(
            worker=worker, tick=self._tick, reason=reason,
            old_topology=old, new_topology=new, plans_dropped=dropped,
            plans_rebuilt=rebuilt, migration=migration, requeued=requeued)
        self.reports.append(report)
        return report

    # -- health ----------------------------------------------------------------
    def stats(self) -> dict:
        states = Counter(ws.state for ws in self.workers.values())
        return {
            "topology": repr(self.topology),
            "workers": {w: ws.state for w, ws in sorted(self.workers.items())},
            "states": dict(states),
            "transitions": len(self.transitions),
            "evictions": sum(1 for t in self.transitions if t.to == EVICTED),
            "rejoins": sum(1 for t in self.transitions if t.to == REJOINED),
            "plan_caches": plan_cache_stats(),
        }


class ElasticServing:
    """Bind a fault script + controller to a :class:`ServeEngine`.

    The engine's ``n_slots`` decode slots are owned ``n_slots //
    n_workers`` per worker.  Each :meth:`tick`: the injector fires its
    scripted faults, surviving workers report step times, the controller
    runs its state machine, and the engine decodes one step.  When a
    worker is evicted its slots are drained — in-flight sequences go back
    through scheduler ``requeue`` (re-admission re-prefills from the
    prompt, so greedy tokens stay bit-identical to a fault-free run), the
    slots go offline so admission never lands on dead hardware, and the
    worker's unclaimed fetch_op tickets are released
    (:meth:`~repro.serve.scheduler.Scheduler.release_claims`)."""

    def __init__(self, engine, script: FaultScript, *, n_workers: int,
                 base_step: float = 1.0, suspect_strikes: int = 2,
                 quarantine_grace: int = 1, probation: int = 3,
                 monitor: StragglerMonitor | None = None):
        if engine.n_slots % n_workers:
            raise ValueError(
                f"n_slots={engine.n_slots} must divide evenly over "
                f"n_workers={n_workers}")
        self.engine = engine
        self.n_workers = n_workers
        self.slots_per_worker = engine.n_slots // n_workers
        self.injector = FaultInjector(script, base_step=base_step)
        self.controller = ElasticController(
            n_workers, monitor=monitor, suspect_strikes=suspect_strikes,
            quarantine_grace=quarantine_grace, probation=probation,
            on_evict=self._evict_worker, on_rejoin=self._rejoin_worker)

    def slots_of(self, worker: int) -> list[int]:
        w0 = worker * self.slots_per_worker
        return list(range(w0, w0 + self.slots_per_worker))

    # -- controller hooks ------------------------------------------------------
    def _evict_worker(self, worker: int) -> int:
        slots = self.slots_of(worker)
        requeued = self.engine.evict_slots(slots, requeue=True)
        self.engine.set_slots_offline(slots, True)
        self.engine.scheduler.release_claims(
            ElasticController.source_of(worker))
        return requeued

    def _rejoin_worker(self, worker: int) -> None:
        self.engine.set_slots_offline(self.slots_of(worker), False)

    # -- driving ---------------------------------------------------------------
    def tick(self) -> None:
        fired = self.injector.advance()
        t = self.injector.tick
        for f in fired:
            self.controller.apply_fault(f, t)
        for w, d in self.injector.durations(self.n_workers).items():
            self.controller.observe_step(w, d, t)
        self.controller.advance(t)
        self.engine.step()

    def run(self, max_ticks: int = 10_000) -> list:
        """Tick until every submitted request completes (or raise)."""
        eng = self.engine
        for _ in range(max_ticks):
            if not (eng.scheduler.pending_count or eng.slot_req):
                return list(eng.done)
            self.tick()
        raise RuntimeError(
            f"elastic run did not drain in {max_ticks} ticks "
            f"(pending={eng.scheduler.pending_count}, "
            f"live={sorted(eng.slot_req)}, "
            f"states={self.controller.stats()['workers']})")

    def stats(self) -> dict:
        return {**self.engine.stats(), "elastic": self.controller.stats(),
                "faults_injected": len(self.injector.injected)}


__all__ = [
    "ElasticController", "ElasticServing", "WorkerState", "Transition",
    "RecoveryReport", "shrink_topology", "migrate_pages",
    "MIGRATION_STREAM", "LIFECYCLE",
    "HEALTHY", "SUSPECT", "QUARANTINED", "EVICTED", "REJOINED",
]
