"""Straggler detection and mitigation policy.

At pod scale the common failure mode is not death but *slowness* (one host at
60 % speed stalls every synchronous collective).  The monitor keeps an EMA of
step times, flags steps exceeding ``threshold × EMA``, and tracks repeat
offenders per source; the policy layer decides between logging, raising (so
the launcher restarts onto a healthy mesh slice), or — on real multi-host
deployments — re-dispatching the slow host's shard.

The monitor is deliberately runtime-agnostic (fed wall-clock step times), so
it is unit-testable without hardware and usable unchanged in the launcher.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    ema: float
    ratio: float
    source: str


class StragglerMonitor:
    def __init__(self, *, threshold: float = 2.0, ema_alpha: float = 0.1,
                 warmup_steps: int = 5, escalate_after: int = 3,
                 on_escalate: Callable[[StragglerEvent], None] | None = None):
        self.threshold = threshold
        self.alpha = ema_alpha
        self.warmup = warmup_steps
        self.escalate_after = escalate_after
        self.on_escalate = on_escalate
        self.ema: float | None = None
        self.seen = 0
        self.events: list[StragglerEvent] = []
        self.offenders: dict[str, int] = defaultdict(int)
        self._t0: float | None = None

    # -- context-manager style per-step timing ------------------------------
    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int, source: str = "local") -> StragglerEvent | None:
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(step, dt, source)

    # -- core logic -----------------------------------------------------------
    def observe(self, step: int, duration: float,
                source: str = "local") -> StragglerEvent | None:
        """Feed one step time.  Returns an event iff it's a straggler step."""
        self.seen += 1
        if self.ema is None:
            self.ema = duration
            return None
        event = None
        if self.seen > self.warmup and duration > self.threshold * self.ema:
            event = StragglerEvent(step, duration, self.ema,
                                   duration / self.ema, source)
            self.events.append(event)
            self.offenders[source] += 1
            if (self.offenders[source] >= self.escalate_after
                    and self.on_escalate is not None):
                self.on_escalate(event)
        else:
            # straggler steps do not poison the EMA
            self.ema = (1 - self.alpha) * self.ema + self.alpha * duration
        return event

    def chronic_offenders(self) -> list[str]:
        return [s for s, n in self.offenders.items()
                if n >= self.escalate_after]


__all__ = ["StragglerMonitor", "StragglerEvent"]
