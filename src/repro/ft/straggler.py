"""Straggler detection and mitigation policy.

At pod scale the common failure mode is not death but *slowness* (one host at
60 % speed stalls every synchronous collective).  The monitor keeps an EMA of
step times, flags steps exceeding ``threshold × EMA``, and tracks repeat
offenders per source; the policy layer decides between logging, raising (so
the launcher restarts onto a healthy mesh slice), or — on real multi-host
deployments — re-dispatching the slow host's shard.

Warmup is *robust*: the first ``warmup_steps`` samples (which include
compile-time spikes and allocator churn) never feed the EMA directly —
the baseline is re-seeded from their **median** each step, so a single slow
warmup step cannot inflate the baseline and mask real stragglers later.
Once armed, only non-straggler steps update the EMA.

The monitor is deliberately runtime-agnostic (fed wall-clock step times), so
it is unit-testable without hardware and usable unchanged in the launcher.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from collections import defaultdict, deque
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    ema: float
    ratio: float
    source: str


class StragglerMonitor:
    def __init__(self, *, threshold: float = 2.0, ema_alpha: float = 0.1,
                 warmup_steps: int = 5, escalate_after: int = 3,
                 on_escalate: Callable[[StragglerEvent], None] | None = None):
        self.threshold = threshold
        self.alpha = ema_alpha
        self.warmup = warmup_steps
        self.escalate_after = escalate_after
        self.on_escalate = on_escalate
        self.ema: float | None = None
        self.seen = 0
        self.events: list[StragglerEvent] = []
        self.offenders: dict[str, int] = defaultdict(int)
        self._t0: float | None = None
        self._warmup_samples: list[float] = []
        # recent healthy (source, duration) samples — what reset(source=)
        # re-seeds the baseline from once the named source's are excluded
        self._recent: deque[tuple[str, float]] = deque(maxlen=32)

    # -- context-manager style per-step timing ------------------------------
    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int, source: str = "local") -> StragglerEvent | None:
        if self._t0 is None:
            raise RuntimeError(
                "StragglerMonitor.stop() without a matching start() — "
                "call start() at the top of the step being timed")
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(step, dt, source)

    # -- core logic -----------------------------------------------------------
    def observe(self, step: int, duration: float,
                source: str = "local") -> StragglerEvent | None:
        """Feed one step time.  Returns an event iff it's a straggler step."""
        self.seen += 1
        if self.seen <= self.warmup:
            # warmup: collect, never flag, and keep the baseline at the
            # median of what has been seen — an outlier warmup step (compile
            # spike, slow first allocation) cannot seed or drag the EMA
            self._warmup_samples.append(duration)
            self._recent.append((source, duration))
            self.ema = statistics.median(self._warmup_samples)
            return None
        if self.ema is None:
            # warmup_steps=0: seed from the first armed sample
            self.ema = duration
            return None
        event = None
        if duration > self.threshold * self.ema:
            event = StragglerEvent(step, duration, self.ema,
                                   duration / self.ema, source)
            self.events.append(event)
            self.offenders[source] += 1
            if (self.offenders[source] >= self.escalate_after
                    and self.on_escalate is not None):
                self.on_escalate(event)
        else:
            # straggler steps do not poison the EMA
            self.ema = (1 - self.alpha) * self.ema + self.alpha * duration
            self._recent.append((source, duration))
        return event

    def reset(self, source: str | None = None) -> None:
        """Clear escalation state.

        With ``source``, clears only that source — the **rejoin** path: a
        worker re-admitted after quarantine must not inherit its old
        offender count (one more slow step would immediately re-escalate)
        nor keep biasing the baseline with its pre-eviction samples.  Its
        events and recent samples are dropped and the EMA is re-seeded from
        the median of the *other* sources' recent healthy steps, so the
        rejoined worker is judged against the surviving mesh's pace.

        Without ``source``, resets the whole monitor to its initial state
        (fresh warmup)."""
        if source is None:
            self.ema = None
            self.seen = 0
            self.events.clear()
            self.offenders.clear()
            self._warmup_samples.clear()
            self._recent.clear()
            return
        self.offenders.pop(source, None)
        self.events = [e for e in self.events if e.source != source]
        kept = [(s, d) for s, d in self._recent if s != source]
        self._recent = deque(kept, maxlen=self._recent.maxlen)
        if kept:
            self.ema = statistics.median(d for _, d in kept)

    def chronic_offenders(self) -> list[str]:
        return [s for s, n in self.offenders.items()
                if n >= self.escalate_after]


__all__ = ["StragglerMonitor", "StragglerEvent"]
