"""Deterministic fault injection — scripted failures for the elastic runtime.

RAMC (Schonbein et al., PAPERS.md) argues transport-level failure and
timeout semantics must be first-class in an RMA runtime rather than assumed
away; foMPI's recovery story only matters if the recovery paths actually
run.  This module makes every failure mode a **reproducible input**: a
:class:`FaultScript` is an ordered list of :class:`Fault` events — seedable
(:meth:`FaultScript.random`), parseable from a CLI spec
(:meth:`FaultScript.parse`), and replayable tick-by-tick through a
:class:`FaultInjector` — so tests, the interpret backend, and benchmarks
exercise quarantine / recompile / migration / re-admission without real
hardware failures, and a hypothesis sweep can shrink a failing script to a
minimal reproducer.

Fault kinds (what the injector does at the scripted tick):

* ``slow_step``   — the worker's observed step time is multiplied by
  ``magnitude`` (feeds the straggler monitor; repeated slow steps escalate);
* ``dead_worker`` — the worker stops responding entirely: quarantined
  immediately, evicted by the controller's recovery pipeline;
* ``lost_doorbell`` — one put_signal doorbell never lands (the RAMC-style
  transport loss): counts a suspect strike without any slow step;
* ``rejoin``      — a previously evicted worker comes back and re-enters
  through probation.
"""
from __future__ import annotations

import dataclasses
import random as _random
import re

FAULT_KINDS = ("slow_step", "dead_worker", "lost_doorbell", "rejoin")

#: CLI shorthand per kind (``FaultScript.parse``): ``dead:3@10`` reads
#: "dead_worker on worker 3 at tick 10"; ``slow:1@4x6`` adds a magnitude.
_SPEC_KINDS = {"slow": "slow_step", "dead": "dead_worker",
               "bell": "lost_doorbell", "rejoin": "rejoin"}
_SPEC_RE = re.compile(
    r"(?P<kind>[a-z_]+):(?P<worker>\d+)@(?P<tick>\d+)(?:x(?P<mag>[\d.]+))?")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scripted failure event."""

    tick: int                  # injector tick the fault fires at
    kind: str                  # one of FAULT_KINDS
    worker: int                # target worker rank
    magnitude: float = 1.0     # slow_step: step-time multiplier

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (one of {FAULT_KINDS})")
        if self.tick < 0 or self.worker < 0:
            raise ValueError(f"fault tick/worker must be >= 0: {self}")
        if self.kind == "slow_step" and self.magnitude <= 1.0:
            raise ValueError(
                f"slow_step magnitude must be > 1 (a multiplier), "
                f"got {self.magnitude}")


class FaultScript:
    """An ordered, replayable list of :class:`Fault` events."""

    def __init__(self, faults=()):
        self.faults = tuple(sorted(faults, key=lambda f: (f.tick, f.worker)))

    def __len__(self) -> int:
        return len(self.faults)

    def __iter__(self):
        return iter(self.faults)

    def at(self, tick: int) -> list[Fault]:
        return [f for f in self.faults if f.tick == tick]

    @property
    def horizon(self) -> int:
        """Last scripted tick (0 for an empty script)."""
        return max((f.tick for f in self.faults), default=0)

    @classmethod
    def random(cls, seed: int, *, n_workers: int, n_faults: int = 3,
               max_tick: int = 20, kinds=("slow_step", "dead_worker",
                                          "lost_doorbell"),
               protect=(0,)) -> "FaultScript":
        """Seedable random script over ``n_workers`` ranks.

        ``protect`` names ranks never targeted (rank 0 by default — the
        controller's survivor anchor, so a script can't evict the whole
        mesh).  At most one ``dead_worker`` per rank is emitted; a dead
        rank draws no further faults.  Uses :mod:`random` with an explicit
        seed — same seed, same script, any process."""
        rng = _random.Random(seed)
        candidates = [w for w in range(n_workers) if w not in set(protect)]
        faults, dead = [], set()
        for _ in range(n_faults):
            alive = [w for w in candidates if w not in dead]
            if not alive:
                break
            kind = rng.choice(list(kinds))
            worker = rng.choice(alive)
            tick = rng.randrange(1, max_tick + 1)
            mag = round(rng.uniform(2.0, 8.0), 2) if kind == "slow_step" \
                else 1.0
            if kind == "dead_worker":
                dead.add(worker)
            faults.append(Fault(tick, kind, worker, mag))
        return cls(faults)

    @classmethod
    def parse(cls, spec: str) -> "FaultScript":
        """Parse a CLI spec: comma-separated ``kind:worker@tick[xmag]``.

        ``"dead:3@10,slow:1@4x6"`` — worker 3 dies at tick 10, worker 1
        runs 6× slow at tick 4.  Kinds: ``slow``, ``dead``, ``bell``,
        ``rejoin`` (or the full names)."""
        faults = []
        for part in filter(None, (p.strip() for p in spec.split(","))):
            m = _SPEC_RE.fullmatch(part)
            if not m:
                raise ValueError(
                    f"bad fault spec {part!r} — expected kind:worker@tick"
                    f"[xmagnitude], e.g. dead:3@10 or slow:1@4x6")
            kind = _SPEC_KINDS.get(m["kind"], m["kind"])
            mag = float(m["mag"]) if m["mag"] else \
                (4.0 if kind == "slow_step" else 1.0)
            faults.append(Fault(int(m["tick"]), kind, int(m["worker"]), mag))
        return cls(faults)

    def __repr__(self) -> str:
        return f"FaultScript({list(self.faults)!r})"


class FaultInjector:
    """Replays a :class:`FaultScript` tick by tick against a worker fleet.

    The injector owns the *physical* failure state (which ranks are dead,
    which run slow); the :class:`~repro.ft.elastic.ElasticController` owns
    the *logical* reaction (suspicion, quarantine, recovery).  Keeping them
    separate is what lets the same script drive a meshless unit test, the
    interpret backend, and an 8-device mdev run identically."""

    def __init__(self, script: FaultScript, *, base_step: float = 1.0):
        self.script = script
        self.base_step = base_step
        self.tick = -1
        self.dead: set[int] = set()
        self.slow: dict[int, float] = {}       # worker -> multiplier
        self.lost_bells: list[int] = []        # workers hit this tick
        self.injected: list[Fault] = []

    def advance(self) -> list[Fault]:
        """Move to the next tick; returns the faults firing on it."""
        self.tick += 1
        fired = self.script.at(self.tick)
        self.lost_bells = []
        for f in fired:
            if f.kind == "dead_worker":
                self.dead.add(f.worker)
                self.slow.pop(f.worker, None)
            elif f.kind == "slow_step":
                if f.worker not in self.dead:
                    self.slow[f.worker] = f.magnitude
            elif f.kind == "lost_doorbell":
                if f.worker not in self.dead:
                    self.lost_bells.append(f.worker)
            elif f.kind == "rejoin":
                self.dead.discard(f.worker)
                self.slow.pop(f.worker, None)
        self.injected.extend(fired)
        return fired

    def alive(self, worker: int) -> bool:
        return worker not in self.dead

    def duration(self, worker: int) -> float | None:
        """This tick's observed step time for ``worker`` — ``None`` when
        the rank is dead (no heartbeat at all, not a slow one)."""
        if worker in self.dead:
            return None
        return self.base_step * self.slow.get(worker, 1.0)

    def durations(self, n_workers: int) -> dict[int, float]:
        """Step times for every rank still alive this tick."""
        out = {}
        for w in range(n_workers):
            d = self.duration(w)
            if d is not None:
                out[w] = d
        return out


__all__ = ["Fault", "FaultScript", "FaultInjector", "FAULT_KINDS"]
