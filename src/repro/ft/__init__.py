"""Fault tolerance: straggler detection, fault injection, elastic recovery.

* :mod:`repro.ft.straggler` — EMA-based slow-worker detection with
  per-source escalation (the controller's sensor).
* :mod:`repro.ft.inject` — deterministic, seedable fault scripts
  (slow-step / dead-worker / lost-doorbell / rejoin) so every recovery
  path runs without real hardware failures.
* :mod:`repro.ft.elastic` — the control plane: worker lifecycle
  (healthy → suspect → quarantined → evicted/rejoined), topology-targeted
  plan recompilation, live KV-page migration, sequence re-admission.

See ``docs/elastic.md``.
"""
from repro.ft.elastic import (
    ElasticController,
    ElasticServing,
    MIGRATION_STREAM,
    RecoveryReport,
    Transition,
    WorkerState,
    migrate_pages,
    shrink_topology,
)
from repro.ft.inject import FAULT_KINDS, Fault, FaultInjector, FaultScript
from repro.ft.straggler import StragglerEvent, StragglerMonitor

__all__ = [
    "StragglerMonitor", "StragglerEvent",
    "Fault", "FaultScript", "FaultInjector", "FAULT_KINDS",
    "ElasticController", "ElasticServing", "WorkerState", "Transition",
    "RecoveryReport", "shrink_topology", "migrate_pages", "MIGRATION_STREAM",
]
