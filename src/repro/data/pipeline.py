"""Deterministic, shardable token pipelines (synthetic + file-backed).

Both sources implement the same contract:

    batches = source.batches(step_start)          # infinite iterator
    batch   = next(batches)                       # numpy, GLOBAL batch
    shard   = source.host_shard(batch, host, n)   # this host's rows

Determinism: batch contents are a pure function of (seed, step), so a
restarted job resumes mid-epoch bit-identically — the property the
checkpoint/restart test asserts.  Sharding is by contiguous row blocks, so
elastic re-runs with a different host count still see the same global batch.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"  # synthetic | file
    path: str | None = None


def _philox(seed: int, step: int, rows: int, cols: int, vocab: int) -> np.ndarray:
    """Counter-based deterministic token block (no RNG state to checkpoint)."""
    rng = np.random.Generator(np.random.Philox(key=seed, counter=step))
    return rng.integers(0, vocab, size=(rows, cols), dtype=np.int32)


class SyntheticLM:
    """Markov-flavoured synthetic LM data: learnable but trivial structure
    (next token = affine function of current + noise) so loss demonstrably
    decreases in examples/integration tests."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        base = _philox(c.seed, step, c.global_batch, c.seq_len + 1, c.vocab)
        # inject structure: token[t+1] ≡ (7·token[t] + 13) mod vocab, 50% of
        # the time — a pattern a model can learn quickly.
        det = (7 * base[:, :-1] + 13) % c.vocab
        mask = _philox(c.seed + 1, step, c.global_batch, c.seq_len, 2)
        nxt = np.where(mask.astype(bool), det, base[:, 1:])
        tokens = base[:, :-1]
        labels = nxt
        return {"tokens": tokens, "labels": labels}

    def batches(self, step_start: int = 0) -> Iterator[dict]:
        step = step_start
        while True:
            yield self.batch_at(step)
            step += 1

    @staticmethod
    def host_shard(batch: dict, host: int, n_hosts: int) -> dict:
        def shard(x):
            rows = x.shape[0]
            assert rows % n_hosts == 0, (rows, n_hosts)
            per = rows // n_hosts
            return x[host * per : (host + 1) * per]
        return {k: shard(v) for k, v in batch.items()}


class FileTokens:
    """Memory-mapped flat token file (uint16/uint32), sequence-packed.

    Deterministic: sequence i of step s starts at a hash-derived offset, so
    restarts and different host counts see identical global batches.
    """

    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")
        if len(self.data) < cfg.seq_len + 2:
            raise ValueError("token file smaller than one sequence")

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        n = len(self.data) - c.seq_len - 1
        offs = _philox(c.seed ^ 0x5EED, step, c.global_batch, 1, n)[:, 0]
        tokens = np.stack([self.data[o : o + c.seq_len] for o in offs]).astype(np.int32)
        labels = np.stack([self.data[o + 1 : o + 1 + c.seq_len] for o in offs]).astype(np.int32)
        return {"tokens": tokens % c.vocab, "labels": labels % c.vocab}

    batches = SyntheticLM.batches
    host_shard = staticmethod(SyntheticLM.host_shard)


def make_source(cfg: DataConfig):
    if cfg.kind == "synthetic":
        return SyntheticLM(cfg)
    if cfg.kind == "file":
        return FileTokens(cfg)
    raise ValueError(f"unknown data kind {cfg.kind!r}")


__all__ = ["DataConfig", "SyntheticLM", "FileTokens", "make_source"]
